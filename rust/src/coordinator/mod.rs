//! L3 coordinator: drives a [`Method`] (server + n workers) against
//! gradient engines, with exact communication accounting and per-phase
//! timing.
//!
//! The front door is the [`Session`] builder: one composable API that
//! selects a [`Driver`], wires engines, streams metrics through
//! [`RoundObserver`]s, and configures checkpointing —
//!
//! ```no_run
//! # use smx::config::ExperimentConfig;
//! # fn demo(cfg: &ExperimentConfig) -> anyhow::Result<()> {
//! let result = smx::coordinator::Session::from_config(cfg).run()?;
//! # let _ = result; Ok(()) }
//! ```
//!
//! Three drivers share the protocol:
//!
//! * [`Driver::Sim`] — deterministic in-process loop (workers execute
//!   sequentially on the calling thread). Used by the figure sweeps,
//!   benches and tests: zero scheduling noise, exact reproducibility.
//! * [`Driver::Threaded`] — one OS thread per worker connected by
//!   fixed-capacity SPSC [`ring`](crate::util::ring) buffers, mirroring a
//!   real parameter-server deployment (optionally core-pinned via
//!   [`RunConfig::pin`]). Engines are constructed *inside* each worker
//!   thread via an [`EngineFactory`] (the PJRT client is not `Send`).
//! * [`Driver::Distributed`] — the same protocol across process
//!   boundaries through the [`wire`](crate::wire) codec + transports
//!   (loopback threads, or the elastic TCP server behind `smx serve`).
//!
//! All drivers seed workers identically, so given the same method +
//! engines they produce *bitwise identical* trajectories (the distributed
//! driver under its lossless `f64` payload) — the invariant checked by
//! `tests/driver_matrix.rs` across the full method × sampling × shard
//! grid, with observers attached and detached.
//!
//! Metrics flow through the [`RoundObserver`] seam: each driver computes
//! a [`RoundRecord`] for round 0, every `record_every`-th round and the
//! final/target round, and hands it to the observer stack. In-memory
//! collection (the classic [`RunResult::records`]) is itself an observer;
//! streaming JSONL/CSV sinks and a checkpoint writer are provided in
//! [`session`]. Both in-process drivers also record *measured*
//! `bytes_up`/`bytes_down` — the exact encoded frame sizes the wire codec
//! would produce under [`RunConfig::payload`] — next to the modeled
//! `bits_up` account.
//!
//! The observer-threaded cores ([`run_sim_observed`] /
//! [`run_threaded_observed`]) are the only per-driver entry points; the
//! pre-`Session` deprecated shims (`run_sim`, `run_threaded`,
//! `wire::run_distributed*`) have been removed — construct a [`Session`]
//! instead.

pub mod membership;
pub mod metrics;
pub mod session;

pub use membership::{
    Membership, MembershipEvent, MembershipState, MemberState, Participation,
};
pub use metrics::{RoundRecord, RoundTotals, RunOutcome, RunResult};
pub use session::{
    load_checkpoint, write_checkpoint, CheckpointObserver, CollectObserver, CsvObserver,
    DistTransport, Driver, DriverKind, JsonlObserver, ObserverControl, RoundObserver, Session,
};

use crate::linalg::vector;
use crate::methods::{Downlink, Method, RoundBuffers, Uplink};
use crate::runtime::GradEngine;
use crate::util::rng::Rng;
use crate::util::ring;
use crate::util::timer::PhaseTimer;
use crate::wire::codec::{self, Payload};
use session::{Tick, Ticker};
use std::sync::Arc;

/// Stopping / recording / checkpointing policy for one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub max_rounds: usize,
    /// stop as soon as residual ≤ target (0.0 disables)
    pub target_residual: f64,
    /// record a metric point every k rounds (round 0 and the final round
    /// are always kept)
    pub record_every: usize,
    pub seed: u64,
    /// float width used for the *modeled* bit accounting. The runner
    /// derives it from the wire payload via
    /// [`WireConfig::effective_float_bits`](crate::config::WireConfig::effective_float_bits)
    /// — the single home of the derivation rules.
    pub float_bits: u32,
    /// wire value payload: what the distributed driver actually encodes,
    /// and what the in-process drivers' measured `bytes_up`/`bytes_down`
    /// accounting assumes
    pub payload: Payload,
    /// pin worker thread `i` to core `i mod cores` in the threaded driver
    /// (`sched_setaffinity`; no-op off Linux). Pinning cannot affect the
    /// trajectory — the protocol is synchronous and deterministic — it
    /// only removes scheduler migration from the hot loop.
    pub pin: bool,
    /// fire [`RoundObserver::on_checkpoint`] every k rounds (0 disables).
    /// The elastic TCP server additionally snapshots worker state and
    /// truncates its replay journal on this cadence (see
    /// [`crate::wire::runtime`]).
    pub checkpoint_every: usize,
    /// partial participation: per-round cohort size τ (None ⇒ all n
    /// workers speak every round). Cohorts are a pure function of
    /// `(seed, n, τ, round)` ([`membership::cohort_mask`]) and uplinks
    /// are reweighted by n/τ before aggregation, identically on every
    /// driver; τ = n short-circuits to exactly the full-participation
    /// path.
    pub participation: Option<usize>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            max_rounds: 1000,
            target_residual: 0.0,
            record_every: 1,
            seed: 0xC0FFEE,
            float_bits: 64,
            payload: Payload::F64,
            pin: false,
            checkpoint_every: 0,
            participation: None,
        }
    }
}

impl RunConfig {
    pub fn new(max_rounds: usize) -> RunConfig {
        RunConfig {
            max_rounds,
            ..Default::default()
        }
    }
}

/// Builds a worker's engine inside its own thread.
pub type EngineFactory = Arc<dyn Fn(usize) -> Box<dyn GradEngine> + Send + Sync>;

fn residual(x: &[f64], x_star: &[f64], denom: f64) -> f64 {
    vector::dist2(x, x_star) / denom
}

/// Modeled bit account of one uplink (`delta` plus ADIANA's optional
/// `delta2`) — shared with the distributed driver so the two accounts
/// cannot drift.
pub(crate) fn bits_of(up: &Uplink, dim: usize, float_bits: u32) -> u64 {
    let mut b = up.delta.bits(dim, float_bits);
    if let Some(d2) = &up.delta2 {
        b += d2.bits(dim, float_bits);
    }
    b
}

/// Deterministic in-process driver core: metrics stream through `obs`,
/// the records themselves are whatever the observer stack keeps (see
/// [`RunOutcome::into_result`]). Prefer [`Session`] with [`Driver::Sim`].
///
/// §Perf: the round loop reuses one [`RoundBuffers`] (a `Downlink` plus
/// one `Uplink` per worker) for the whole run, so in steady state it
/// performs zero heap allocations per round (asserted in
/// `tests/alloc_free.rs` for dcgd+/diana+; observer calls hand out
/// stack-built records by reference).
pub fn run_sim_observed(
    method: &mut Method,
    engines: &mut [Box<dyn GradEngine>],
    x_star: &[f64],
    cfg: &RunConfig,
    obs: &mut dyn RoundObserver,
) -> RunOutcome {
    assert_eq!(method.workers.len(), engines.len());
    let n = method.workers.len();
    let dim = method.server.dim();
    let base = Rng::new(cfg.seed);
    let mut server_rng = base.derive(u64::MAX);
    let mut worker_rngs: Vec<Rng> = (0..n).map(|i| base.derive(i as u64)).collect();

    let denom = vector::dist2(method.server.iterate(), x_star).max(1e-300);
    let mut acc = RoundTotals::default();
    let mut phases = PhaseTimer::new();
    let ticker = Ticker::new(cfg);
    let mut stopped = ticker.start(obs);
    let mut reached = false;
    let mut rounds_run = 0;
    let mut bufs = RoundBuffers::new(n);
    // partial participation: τ = n (or None) is a strict no-op — no RNG
    // stream is consumed and no uplink is touched (config validation
    // already proved τ ≥ 1)
    let mut participation = Participation::from_run(cfg.participation, cfg.seed, n)
        .expect("participation validated at config time")
        .filter(|p| !p.is_full());
    let weight = participation.as_ref().map_or(1.0, Participation::weight);

    if !stopped {
        for round in 1..=cfg.max_rounds {
            rounds_run = round;
            let RoundBuffers { down, ups } = &mut bufs;
            phases.time("server_downlink", || method.server.downlink_into(&mut *down));
            let cohort = participation.as_mut().map(|p| p.draw(round as u64));
            let tau = cohort.as_ref().map_or(n, |m| m.iter().filter(|&&b| b).count());
            acc.coords_down += (down.coords() * tau) as u64;
            acc.bytes_down += (codec::downlink_frame_len(&*down, cfg.payload) * tau) as u64;

            for i in 0..n {
                let up = &mut ups[i];
                if let Some(mask) = &cohort {
                    if !mask[i] {
                        // sampled out: the worker computes nothing, its
                        // state does not advance, and its slot must not
                        // leak last round's message into apply
                        membership::clear_uplink(up);
                        continue;
                    }
                }
                phases.time("worker_round", || {
                    method.workers[i].round_into(
                        &*down,
                        engines[i].as_mut(),
                        &mut worker_rngs[i],
                        &mut *up,
                    )
                });
                acc.coords_up += up.coords() as u64;
                acc.bits_up += bits_of(up, dim, cfg.float_bits);
                acc.bytes_up += codec::uplink_frame_len(&*up, i, cfg.payload) as u64;
            }

            // reweight by n/τ after accounting (the wire carries the
            // unscaled values) and before aggregation — the unbiasedness
            // correction, applied identically by every driver
            if let Some(mask) = &cohort {
                for (i, up) in ups.iter_mut().enumerate() {
                    if mask[i] {
                        membership::reweight_uplink(up, weight);
                    }
                }
            }

            phases.time("server_apply", || {
                method.server.apply(&*ups, &mut server_rng)
            });

            let res = residual(method.server.iterate(), x_star, denom);
            match ticker.tick(round, res, &acc, method.server.iterate(), &phases, obs) {
                Tick::Continue => {}
                Tick::ReachedTarget => {
                    reached = true;
                    break;
                }
                Tick::Stopped => {
                    stopped = true;
                    break;
                }
            }
        }
    }

    RunOutcome {
        method: method.name.clone(),
        final_x: method.server.iterate().to_vec(),
        rounds_run,
        reached_target: reached,
        stopped_by_observer: stopped,
        phases,
    }
}

enum ToWorker {
    Round(Arc<Downlink>),
    /// Hand a consumed uplink buffer back to its worker for reuse (§Perf:
    /// keeps the steady-state round free of `SparseMsg` reallocation).
    Recycle(Uplink),
    Stop,
}

/// At most a `Round` and a `Recycle` are in flight to a worker at once,
/// plus the final `Stop`; one spare slot keeps the send side from ever
/// brushing the full-ring wait in the steady state.
const TO_WORKER_RING_CAP: usize = 4;

/// Threaded parameter-server driver core: one thread per worker,
/// synchronous rounds, metrics through `obs`. Consumes the method (worker
/// halves move into their threads). Prefer [`Session`] with
/// [`Driver::Threaded`].
///
/// §Perf: each worker is connected by a pair of fixed-capacity SPSC
/// [`ring`](crate::util::ring) channels (mpsc's per-send block allocation
/// was the last per-round allocation source). Uplink buffers cycle
/// server→worker via `ToWorker::Recycle`, workers drop their downlink
/// `Arc` clone *before* sending the uplink so the gather barrier
/// guarantees `Arc::get_mut` succeeds and the broadcast buffer is
/// rewritten in place — the steady-state coordinator round is literally
/// allocation-free (asserted in `tests/alloc_free.rs`, observers
/// included).
///
/// With [`RunConfig::pin`], worker `i` pins itself to core `i mod cores`
/// before building its engine (`sched_setaffinity`; no-op off Linux).
/// Pinning cannot change results — the protocol is synchronous — and the
/// driver-identity tests run a pinned column to keep that true.
pub fn run_threaded_observed(
    mut method: Method,
    engine_factory: EngineFactory,
    x_star: &[f64],
    cfg: &RunConfig,
    obs: &mut dyn RoundObserver,
) -> RunOutcome {
    let n = method.workers.len();
    let dim = method.server.dim();
    let base = Rng::new(cfg.seed);
    let mut server_rng = base.derive(u64::MAX);
    let pin = cfg.pin;

    // spawn workers: one SPSC ring per direction per worker
    let mut to_workers: Vec<ring::RingSender<ToWorker>> = Vec::with_capacity(n);
    let mut from_workers: Vec<ring::RingReceiver<Uplink>> = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (i, mut algo) in method.workers.drain(..).enumerate() {
        let (tx, rx) = ring::ring::<ToWorker>(TO_WORKER_RING_CAP);
        // capacity 1: a worker sends exactly one uplink per round and the
        // server pops it within the same round's gather
        let (up_tx, up_rx) = ring::ring::<Uplink>(1);
        to_workers.push(tx);
        from_workers.push(up_rx);
        let factory = engine_factory.clone();
        let mut rng = base.derive(i as u64);
        handles.push(std::thread::spawn(move || {
            if pin {
                crate::util::affinity::pin_to_core(i);
            }
            let mut engine = factory(i);
            let mut spare: Vec<Uplink> = Vec::new();
            while let Ok(msg) = rx.recv() {
                match msg {
                    ToWorker::Round(down) => {
                        let mut up = spare.pop().unwrap_or_default();
                        algo.round_into(&down, engine.as_mut(), &mut rng, &mut up);
                        // Drop our downlink clone before handing the
                        // uplink over: the ring's happens-before edge then
                        // guarantees the server sees refcount 1 after the
                        // gather, keeping its in-place rewrite alloc-free.
                        drop(down);
                        if up_tx.send(up).is_err() {
                            break;
                        }
                    }
                    ToWorker::Recycle(up) => spare.push(up),
                    ToWorker::Stop => break,
                }
            }
        }));
    }

    let denom = vector::dist2(method.server.iterate(), x_star).max(1e-300);
    let mut acc = RoundTotals::default();
    let mut phases = PhaseTimer::new();
    let ticker = Ticker::new(cfg);
    let mut stopped = ticker.start(obs);
    let mut reached = false;
    let mut rounds_run = 0;
    let mut ups: Vec<Uplink> = (0..n).map(|_| Uplink::default()).collect();
    // The downlink Arc persists across rounds: once the workers have
    // dropped their clones (the synchronous gather guarantees they are
    // done with it), `Arc::get_mut` succeeds and the buffer is rewritten
    // in place — no per-round Arc or payload allocation in steady state.
    let mut down: Arc<Downlink> = Arc::new(Downlink::Init { x: Vec::new() });
    // partial participation: sampled-out workers receive neither a Round
    // nor a Recycle and simply block on their ring until sampled back in
    // — exactly the cheap idling the distributed driver gets from
    // epoch-frame heartbeats
    let mut participation = Participation::from_run(cfg.participation, cfg.seed, n)
        .expect("participation validated at config time")
        .filter(|p| !p.is_full());
    let weight = participation.as_ref().map_or(1.0, Participation::weight);

    if !stopped {
        for round in 1..=cfg.max_rounds {
            rounds_run = round;
            phases.time("server_downlink", || match Arc::get_mut(&mut down) {
                Some(d) => method.server.downlink_into(d),
                None => {
                    // unreachable in practice: every worker drops its clone
                    // before its uplink send, and the previous round's gather
                    // synchronized with all n sends — kept as a safe fallback
                    // (the alloc_free test would flag it if it ever fired)
                    let mut fresh = Downlink::Init { x: Vec::new() };
                    method.server.downlink_into(&mut fresh);
                    down = Arc::new(fresh);
                }
            });
            let cohort = participation.as_mut().map(|p| p.draw(round as u64));
            let tau = cohort.as_ref().map_or(n, |m| m.iter().filter(|&&b| b).count());
            acc.coords_down += (down.coords() * tau) as u64;
            acc.bytes_down += (codec::downlink_frame_len(&down, cfg.payload) * tau) as u64;
            phases.time("scatter", || {
                for (i, tx) in to_workers.iter().enumerate() {
                    if cohort.as_ref().map_or(true, |m| m[i])
                        && tx.send(ToWorker::Round(down.clone())).is_err()
                    {
                        panic!("worker {i} died");
                    }
                }
            });
            phases.time("gather", || {
                // fixed worker order: each ring is SPSC, so popping worker i's
                // ring blocks exactly until its round is done — the barrier is
                // complete after the loop, same as the shared-channel gather
                for (i, up_rx) in from_workers.iter().enumerate() {
                    if !cohort.as_ref().map_or(true, |m| m[i]) {
                        membership::clear_uplink(&mut ups[i]);
                        continue;
                    }
                    let up = up_rx.recv().expect("worker channel closed");
                    acc.coords_up += up.coords() as u64;
                    acc.bits_up += bits_of(&up, dim, cfg.float_bits);
                    acc.bytes_up += codec::uplink_frame_len(&up, i, cfg.payload) as u64;
                    ups[i] = up;
                }
            });
            // unbiasedness reweight by n/τ, after accounting, before apply
            if let Some(mask) = &cohort {
                for (i, up) in ups.iter_mut().enumerate() {
                    if mask[i] {
                        membership::reweight_uplink(up, weight);
                    }
                }
            }
            phases.time("server_apply", || {
                method.server.apply(&ups, &mut server_rng)
            });
            // hand the consumed uplink buffers back to their workers
            // (sampled-out workers sent nothing and get nothing back —
            // recycling into an idle worker would grow its spare stack)
            for (i, tx) in to_workers.iter().enumerate() {
                if cohort.as_ref().map_or(true, |m| m[i]) {
                    let _ = tx.send(ToWorker::Recycle(std::mem::take(&mut ups[i])));
                }
            }

            let res = residual(method.server.iterate(), x_star, denom);
            match ticker.tick(round, res, &acc, method.server.iterate(), &phases, obs) {
                Tick::Continue => {}
                Tick::ReachedTarget => {
                    reached = true;
                    break;
                }
                Tick::Stopped => {
                    stopped = true;
                    break;
                }
            }
        }
    }

    for tx in &to_workers {
        let _ = tx.send(ToWorker::Stop);
    }
    for h in handles {
        let _ = h.join();
    }

    RunOutcome {
        method: method.name.clone(),
        final_x: method.server.iterate().to_vec(),
        rounds_run,
        reached_target: reached,
        stopped_by_observer: stopped,
        phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::methods::{build, MethodSpec};
    use crate::objective::{Problem, Smoothness};
    use crate::runtime::native::NativeEngine;
    use crate::sampling::SamplingKind;

    fn setup() -> (Vec<crate::data::Shard>, Smoothness, Vec<f64>) {
        let ds = synth::generate(&synth::tiny_spec(), 11);
        let (_, shards) = ds.prepare(4, 11);
        let sm = Smoothness::build(&shards, 1e-3);
        let problem = Problem::from_shards(&shards, 1e-3);
        let sol = crate::methods::solve::solve_opt(&problem, &sm, 1e-13, 20_000);
        (shards, sm, sol.x_star)
    }

    fn engines(shards: &[crate::data::Shard]) -> Vec<Box<dyn GradEngine>> {
        shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
            .collect()
    }

    /// The shim body, sans deprecation: collect + core.
    fn sim(
        method: &mut Method,
        engines: &mut [Box<dyn GradEngine>],
        x_star: &[f64],
        cfg: &RunConfig,
    ) -> RunResult {
        let mut collect = CollectObserver::for_cfg(cfg);
        let out = run_sim_observed(method, engines, x_star, cfg, &mut collect);
        out.into_result(collect.into_records())
    }

    #[test]
    fn sim_driver_dgd_converges() {
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("dgd", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 1000,
            target_residual: 1e-8,
            ..Default::default()
        };
        let r = sim(&mut m, &mut eng, &x_star, &cfg);
        assert!(r.reached_target, "final residual {}", r.final_residual());
    }

    // sim ≡ threaded ≡ distributed(loopback) bitwise identity is covered
    // by the table-driven matrix test in `tests/driver_matrix.rs`
    // ({3 methods × 2 samplings × 2 shard counts}), built via `Session`.

    #[test]
    fn record_every_thins_records() {
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("dcgd", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 100,
            record_every: 10,
            ..Default::default()
        };
        let r = sim(&mut m, &mut eng, &x_star, &cfg);
        assert_eq!(r.records.len(), 11); // round 0 + 10 checkpoints
    }

    #[test]
    fn observer_early_stop_ends_run() {
        struct StopAt(usize);
        impl RoundObserver for StopAt {
            fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
                if rec.round >= self.0 {
                    ObserverControl::Stop
                } else {
                    ObserverControl::Continue
                }
            }
        }
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("dcgd+", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 100,
            ..Default::default()
        };
        let mut obs = StopAt(7);
        let out = run_sim_observed(&mut m, &mut eng, &x_star, &cfg, &mut obs);
        assert_eq!(out.rounds_run, 7);
        assert!(out.stopped_by_observer);
        assert!(!out.reached_target);
    }

    #[test]
    fn checkpoint_hook_fires_on_cadence() {
        struct Count(Vec<usize>, usize);
        impl RoundObserver for Count {
            fn on_checkpoint(&mut self, round: usize, x: &[f64]) {
                self.0.push(round);
                self.1 = x.len();
            }
        }
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("diana+", 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 25,
            checkpoint_every: 10,
            ..Default::default()
        };
        let mut obs = Count(Vec::new(), 0);
        let out = run_sim_observed(&mut m, &mut eng, &x_star, &cfg, &mut obs);
        assert_eq!(out.rounds_run, 25);
        assert_eq!(obs.0, vec![10, 20]);
        assert_eq!(obs.1, sm.dim);
    }

    #[test]
    fn communication_accounting_dgd_dense() {
        let (shards, sm, x_star) = setup();
        let n = shards.len() as u64;
        let d = sm.dim as u64;
        let spec = MethodSpec::new("dgd", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 5,
            ..Default::default()
        };
        let r = sim(&mut m, &mut eng, &x_star, &cfg);
        let last = r.records.last().unwrap();
        assert_eq!(last.coords_up, 5 * n * d);
        assert_eq!(last.coords_down, 5 * n * d);
    }

    #[test]
    fn round_buffers_are_reused_in_steady_state() {
        // §Perf invariant: after warmup (plus an explicit reserve to the
        // worst-case message size), the round pipeline never reallocates
        // its Uplink/Downlink buffers — pointers and capacities stay put.
        use crate::methods::{sync_round, RoundBuffers};

        let (shards, sm, _) = setup();
        let dim = sm.dim;
        for name in ["dcgd+", "diana+"] {
            let spec = MethodSpec::new(name, 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; dim]);
            let mut m = build(&spec, &sm).unwrap();
            let mut eng = engines(&shards);
            let base = Rng::new(7);
            let mut server_rng = base.derive(u64::MAX);
            let mut worker_rngs: Vec<Rng> =
                (0..shards.len()).map(|i| base.derive(i as u64)).collect();
            let mut bufs = RoundBuffers::new(shards.len());

            // warmup: let every buffer reach its steady shape
            for _ in 0..20 {
                sync_round(&mut m, &mut eng, &mut server_rng, &mut worker_rngs, &mut bufs);
            }
            // worst case: a sketch can select all d coordinates
            for up in &mut bufs.ups {
                up.delta.idx.reserve(dim);
                up.delta.val.reserve(dim);
            }
            let up_ptrs: Vec<(*const u32, *const f64)> = bufs
                .ups
                .iter()
                .map(|u| (u.delta.idx.as_ptr(), u.delta.val.as_ptr()))
                .collect();
            let down_ptr = match &bufs.down {
                crate::methods::Downlink::Dense { x, .. } => x.as_ptr(),
                _ => panic!("{name} should broadcast dense"),
            };

            for _ in 0..50 {
                sync_round(&mut m, &mut eng, &mut server_rng, &mut worker_rngs, &mut bufs);
            }
            for (u, &(ip, vp)) in bufs.ups.iter().zip(&up_ptrs) {
                assert_eq!(u.delta.idx.as_ptr(), ip, "{name}: uplink idx buffer moved");
                assert_eq!(u.delta.val.as_ptr(), vp, "{name}: uplink val buffer moved");
            }
            match &bufs.down {
                crate::methods::Downlink::Dense { x, .. } => {
                    assert_eq!(x.as_ptr(), down_ptr, "{name}: downlink buffer moved")
                }
                _ => panic!("{name} should broadcast dense"),
            }
        }
    }

    #[test]
    fn round_into_matches_round_fallback() {
        // The buffer-reusing protocol must be bitwise identical to the
        // allocating default path for every method.
        let (shards, sm, x_star) = setup();
        for name in crate::methods::METHOD_NAMES {
            let sm_local = if name == "diana++" {
                let ds = synth::generate(&synth::tiny_spec(), 11);
                let (global, _) = ds.prepare(4, 11);
                Smoothness::build(&shards, 1e-3).with_global(&global.a)
            } else {
                sm.clone()
            };
            let spec = MethodSpec::new(name, 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
            let cfg = RunConfig {
                max_rounds: 25,
                ..Default::default()
            };

            // reference: default-impl fallback (round/downlink) through a
            // hand-rolled loop identical to the pre-refactor driver
            let mut m_ref = build(&spec, &sm_local).unwrap();
            let mut eng_ref = engines(&shards);
            let base = Rng::new(cfg.seed);
            let mut server_rng = base.derive(u64::MAX);
            let mut worker_rngs: Vec<Rng> =
                (0..shards.len()).map(|i| base.derive(i as u64)).collect();
            for _ in 0..cfg.max_rounds {
                let down = m_ref.server.downlink();
                let ups: Vec<Uplink> = m_ref
                    .workers
                    .iter_mut()
                    .zip(eng_ref.iter_mut())
                    .zip(worker_rngs.iter_mut())
                    .map(|((w, e), rng)| w.round(&down, e.as_mut(), rng))
                    .collect();
                m_ref.server.apply(&ups, &mut server_rng);
            }

            let mut m_new = build(&spec, &sm_local).unwrap();
            let mut eng_new = engines(&shards);
            let r_new = sim(&mut m_new, &mut eng_new, &x_star, &cfg);

            assert_eq!(
                m_ref.server.iterate(),
                &r_new.final_x[..],
                "{name}: round_into diverged from round"
            );
        }
    }

    #[test]
    fn tau_one_sends_about_one_coordinate_per_worker() {
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("dcgd+", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let rounds = 200;
        let cfg = RunConfig {
            max_rounds: rounds,
            record_every: rounds,
            ..Default::default()
        };
        let r = sim(&mut m, &mut eng, &x_star, &cfg);
        let per_round_per_worker =
            r.records.last().unwrap().coords_up as f64 / (rounds as f64 * shards.len() as f64);
        assert!(
            (per_round_per_worker - 1.0).abs() < 0.3,
            "E|S| drifted: {per_round_per_worker}"
        );
    }

}
