//! L3 coordinator: drives a [`Method`] (server + n workers) against
//! gradient engines, with exact communication accounting and per-phase
//! timing.
//!
//! Two drivers share the protocol:
//!
//! * [`run_sim`] — deterministic in-process loop (workers execute
//!   sequentially on the calling thread). Used by the figure sweeps,
//!   benches and tests: zero scheduling noise, exact reproducibility.
//! * [`run_threaded`] — one OS thread per worker connected by mpsc
//!   channels, mirroring a real parameter-server deployment. Engines are
//!   constructed *inside* each worker thread via an [`EngineFactory`]
//!   (the PJRT client is not `Send`). Used by the e2e example and the
//!   throughput benches.
//!
//! Both drivers seed workers identically, so given the same method +
//! engines they produce *bitwise identical* trajectories — an invariant
//! checked in the tests below.

pub mod metrics;

pub use metrics::{RoundRecord, RunResult};

use crate::linalg::vector;
use crate::methods::{Downlink, Method, Uplink};
use crate::runtime::GradEngine;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Stopping / recording policy for one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub max_rounds: usize,
    /// stop as soon as residual ≤ target (0.0 disables)
    pub target_residual: f64,
    /// record a metric point every k rounds (round 0 and the final round
    /// are always kept)
    pub record_every: usize,
    pub seed: u64,
    /// float width used for bit accounting (64 for the f64 pipeline)
    pub float_bits: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            max_rounds: 1000,
            target_residual: 0.0,
            record_every: 1,
            seed: 0xC0FFEE,
            float_bits: 64,
        }
    }
}

impl RunConfig {
    pub fn new(max_rounds: usize) -> RunConfig {
        RunConfig {
            max_rounds,
            ..Default::default()
        }
    }
}

/// Builds a worker's engine inside its own thread.
pub type EngineFactory = Arc<dyn Fn(usize) -> Box<dyn GradEngine> + Send + Sync>;

struct Accounting {
    coords_up: u64,
    bits_up: u64,
    coords_down: u64,
}

fn residual(x: &[f64], x_star: &[f64], denom: f64) -> f64 {
    vector::dist2(x, x_star) / denom
}

fn bits_of(up: &Uplink, dim: usize, float_bits: u32) -> u64 {
    let mut b = up.delta.bits(dim, float_bits);
    if let Some(d2) = &up.delta2 {
        b += d2.bits(dim, float_bits);
    }
    b
}

/// Deterministic in-process driver.
pub fn run_sim(
    method: &mut Method,
    engines: &mut [Box<dyn GradEngine>],
    x_star: &[f64],
    cfg: &RunConfig,
) -> RunResult {
    assert_eq!(method.workers.len(), engines.len());
    let n = method.workers.len();
    let dim = method.server.dim();
    let record_every = cfg.record_every.max(1);
    let base = Rng::new(cfg.seed);
    let mut server_rng = base.derive(u64::MAX);
    let mut worker_rngs: Vec<Rng> = (0..n).map(|i| base.derive(i as u64)).collect();

    let denom = vector::dist2(method.server.iterate(), x_star).max(1e-300);
    let mut acc = Accounting {
        coords_up: 0,
        bits_up: 0,
        coords_down: 0,
    };
    let mut phases = PhaseTimer::new();
    let mut records = vec![RoundRecord {
        round: 0,
        residual: 1.0,
        coords_up: 0,
        bits_up: 0,
        coords_down: 0,
        wall_secs: 0.0,
    }];
    let t0 = Instant::now();
    let mut reached = false;
    let mut rounds_run = 0;

    for round in 1..=cfg.max_rounds {
        rounds_run = round;
        let down = phases.time("server_downlink", || method.server.downlink());
        acc.coords_down += (down.coords() * n) as u64;

        let mut ups: Vec<Uplink> = Vec::with_capacity(n);
        for i in 0..n {
            let up = phases.time("worker_round", || {
                method.workers[i].round(&down, engines[i].as_mut(), &mut worker_rngs[i])
            });
            acc.coords_up += up.coords() as u64;
            acc.bits_up += bits_of(&up, dim, cfg.float_bits);
            ups.push(up);
        }

        phases.time("server_apply", || method.server.apply(&ups, &mut server_rng));

        let res = residual(method.server.iterate(), x_star, denom);
        let hit_target = cfg.target_residual > 0.0 && res <= cfg.target_residual;
        if round % record_every == 0 || round == cfg.max_rounds || hit_target {
            records.push(RoundRecord {
                round,
                residual: res,
                coords_up: acc.coords_up,
                bits_up: acc.bits_up,
                coords_down: acc.coords_down,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
        }
        if hit_target {
            reached = true;
            break;
        }
    }

    RunResult {
        method: method.name.clone(),
        records,
        final_x: method.server.iterate().to_vec(),
        rounds_run,
        reached_target: reached,
        phases,
    }
}

enum ToWorker {
    Round(Arc<Downlink>),
    Stop,
}

/// Threaded parameter-server driver: one thread per worker, synchronous
/// rounds. Consumes the method (worker halves move into their threads).
pub fn run_threaded(
    mut method: Method,
    engine_factory: EngineFactory,
    x_star: &[f64],
    cfg: &RunConfig,
) -> RunResult {
    let n = method.workers.len();
    let dim = method.server.dim();
    let record_every = cfg.record_every.max(1);
    let base = Rng::new(cfg.seed);
    let mut server_rng = base.derive(u64::MAX);

    // spawn workers
    let mut to_workers: Vec<mpsc::Sender<ToWorker>> = Vec::with_capacity(n);
    let (up_tx, up_rx) = mpsc::channel::<(usize, Uplink)>();
    let mut handles = Vec::with_capacity(n);
    for (i, mut algo) in method.workers.drain(..).enumerate() {
        let (tx, rx) = mpsc::channel::<ToWorker>();
        to_workers.push(tx);
        let up_tx = up_tx.clone();
        let factory = engine_factory.clone();
        let mut rng = base.derive(i as u64);
        handles.push(std::thread::spawn(move || {
            let mut engine = factory(i);
            while let Ok(msg) = rx.recv() {
                match msg {
                    ToWorker::Round(down) => {
                        let up = algo.round(&down, engine.as_mut(), &mut rng);
                        if up_tx.send((i, up)).is_err() {
                            break;
                        }
                    }
                    ToWorker::Stop => break,
                }
            }
        }));
    }
    drop(up_tx);

    let denom = vector::dist2(method.server.iterate(), x_star).max(1e-300);
    let mut acc = Accounting {
        coords_up: 0,
        bits_up: 0,
        coords_down: 0,
    };
    let mut phases = PhaseTimer::new();
    let mut records = vec![RoundRecord {
        round: 0,
        residual: 1.0,
        coords_up: 0,
        bits_up: 0,
        coords_down: 0,
        wall_secs: 0.0,
    }];
    let t0 = Instant::now();
    let mut reached = false;
    let mut rounds_run = 0;
    let mut ups_buf: Vec<Option<Uplink>> = (0..n).map(|_| None).collect();

    for round in 1..=cfg.max_rounds {
        rounds_run = round;
        let down = Arc::new(phases.time("server_downlink", || method.server.downlink()));
        acc.coords_down += (down.coords() * n) as u64;
        phases.time("scatter", || {
            for tx in &to_workers {
                tx.send(ToWorker::Round(down.clone())).expect("worker died");
            }
        });
        phases.time("gather", || {
            for _ in 0..n {
                let (i, up) = up_rx.recv().expect("worker channel closed");
                acc.coords_up += up.coords() as u64;
                acc.bits_up += bits_of(&up, dim, cfg.float_bits);
                ups_buf[i] = Some(up);
            }
        });
        let ups: Vec<Uplink> = ups_buf.iter_mut().map(|u| u.take().unwrap()).collect();
        phases.time("server_apply", || method.server.apply(&ups, &mut server_rng));

        let res = residual(method.server.iterate(), x_star, denom);
        let hit_target = cfg.target_residual > 0.0 && res <= cfg.target_residual;
        if round % record_every == 0 || round == cfg.max_rounds || hit_target {
            records.push(RoundRecord {
                round,
                residual: res,
                coords_up: acc.coords_up,
                bits_up: acc.bits_up,
                coords_down: acc.coords_down,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
        }
        if hit_target {
            reached = true;
            break;
        }
    }

    for tx in &to_workers {
        let _ = tx.send(ToWorker::Stop);
    }
    for h in handles {
        let _ = h.join();
    }

    RunResult {
        method: method.name.clone(),
        records,
        final_x: method.server.iterate().to_vec(),
        rounds_run,
        reached_target: reached,
        phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::methods::{build, MethodSpec};
    use crate::objective::{Problem, Smoothness};
    use crate::runtime::native::NativeEngine;
    use crate::sampling::SamplingKind;

    fn setup() -> (Vec<crate::data::Shard>, Smoothness, Vec<f64>) {
        let ds = synth::generate(&synth::tiny_spec(), 11);
        let (_, shards) = ds.prepare(4, 11);
        let sm = Smoothness::build(&shards, 1e-3);
        let problem = Problem::from_shards(&shards, 1e-3);
        let sol = crate::methods::solve::solve_opt(&problem, &sm, 1e-13, 20_000);
        (shards, sm, sol.x_star)
    }

    fn engines(shards: &[crate::data::Shard]) -> Vec<Box<dyn GradEngine>> {
        shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
            .collect()
    }

    #[test]
    fn sim_driver_dgd_converges() {
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("dgd", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 1000,
            target_residual: 1e-8,
            ..Default::default()
        };
        let r = run_sim(&mut m, &mut eng, &x_star, &cfg);
        assert!(r.reached_target, "final residual {}", r.final_residual());
    }

    #[test]
    fn sim_and_threaded_agree_bitwise() {
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new(
            "diana+",
            2.0,
            SamplingKind::ImportanceDiana,
            1e-3,
            vec![0.0; sm.dim],
        );
        let cfg = RunConfig {
            max_rounds: 50,
            ..Default::default()
        };

        let mut m1 = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let r1 = run_sim(&mut m1, &mut eng, &x_star, &cfg);

        let m2 = build(&spec, &sm).unwrap();
        let shards2 = shards.clone();
        let factory: EngineFactory = Arc::new(move |i| {
            Box::new(NativeEngine::from_shard(&shards2[i], 1e-3)) as Box<dyn GradEngine>
        });
        let r2 = run_threaded(m2, factory, &x_star, &cfg);

        assert_eq!(r1.final_x, r2.final_x, "drivers diverged");
        assert_eq!(
            r1.records.last().unwrap().coords_up,
            r2.records.last().unwrap().coords_up
        );
    }

    #[test]
    fn record_every_thins_records() {
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("dcgd", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 100,
            record_every: 10,
            ..Default::default()
        };
        let r = run_sim(&mut m, &mut eng, &x_star, &cfg);
        assert_eq!(r.records.len(), 11); // round 0 + 10 checkpoints
    }

    #[test]
    fn communication_accounting_dgd_dense() {
        let (shards, sm, x_star) = setup();
        let n = shards.len() as u64;
        let d = sm.dim as u64;
        let spec = MethodSpec::new("dgd", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let cfg = RunConfig {
            max_rounds: 5,
            ..Default::default()
        };
        let r = run_sim(&mut m, &mut eng, &x_star, &cfg);
        let last = r.records.last().unwrap();
        assert_eq!(last.coords_up, 5 * n * d);
        assert_eq!(last.coords_down, 5 * n * d);
    }

    #[test]
    fn tau_one_sends_about_one_coordinate_per_worker() {
        let (shards, sm, x_star) = setup();
        let spec = MethodSpec::new("dcgd+", 1.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut eng = engines(&shards);
        let rounds = 200;
        let cfg = RunConfig {
            max_rounds: rounds,
            record_every: rounds,
            ..Default::default()
        };
        let r = run_sim(&mut m, &mut eng, &x_star, &cfg);
        let per_round_per_worker =
            r.records.last().unwrap().coords_up as f64 / (rounds as f64 * shards.len() as f64);
        assert!(
            (per_round_per_worker - 1.0).abs() < 0.3,
            "E|S| drifted: {per_round_per_worker}"
        );
    }
}
