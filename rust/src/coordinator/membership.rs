//! Membership: the coordinator's epoch state machine and the per-round
//! client-sampling (partial participation) policy.
//!
//! Two cooperating pieces live here:
//!
//! * [`Participation`] — the **sampler** every driver shares. With
//!   `--participation tau=K` (`wire.participation`) each round draws an
//!   unbiased cohort S ⊆ [n] of exactly τ shards; only cohort members
//!   compute and uplink, and the server reweights their messages by
//!   n/τ before applying so the aggregate stays an unbiased estimator
//!   of the full-participation gradient (the DIANA line's
//!   partial-participation analysis, Mishchenko et al. 1901.09269).
//!   The cohort for round `r` is a **pure function** of
//!   `(seed, n, τ, r)` — no sequential sampler state — so the sim,
//!   threaded and distributed drivers draw identical cohorts with zero
//!   coordination, and a rejoining or late-joining worker can recompute
//!   any historical cohort locally during journal replay. At τ = n the
//!   sampler is a strict no-op: no RNG stream is consumed, no uplink is
//!   scaled, and the trajectory is bitwise identical to a build without
//!   this module.
//!
//! * [`Membership`] — the **state machine** the elastic server drives
//!   (`WaitingForMembers → Warmup → RoundActive → Cooldown`), replacing
//!   the serve loop's ad-hoc accept/rejoin flags with explicit,
//!   validated transitions that emit [`MembershipEvent`]s. The serve
//!   loop *consumes* those events (registry gauges, `RL_MEMBERSHIP`
//!   run-log records) instead of computing them inline; illegal
//!   transitions are rejected with an error rather than silently
//!   absorbed (table-driven tests in `tests/membership.rs`).
//!
//! Epochs number membership *generations*: the epoch rolls when the
//! run activates and whenever composition changes (late join, evict).
//! The cohort draw deliberately does **not** depend on the epoch —
//! that is what keeps a late joiner from perturbing the trajectory.

use crate::methods::Uplink;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

/// RNG-stream label for the cohort sampler, disjoint from the worker
/// streams (`derive(i)`, i < n) and the server stream
/// (`derive(u64::MAX)`).
pub const MEMBERSHIP_STREAM: u64 = u64::MAX - 1;

/// Draw round `round`'s cohort into `mask` (`mask[s]` ⇔ shard `s` is
/// sampled in). Pure in `(seed, n, tau, round)`: a partial Fisher–Yates
/// shuffle over `[0, n)` under `Rng::new(seed).derive(MEMBERSHIP_STREAM)
/// .derive(round)`, keeping the first `tau` picks. `scratch` is reused
/// across calls to keep the per-round draw allocation-free.
pub fn cohort_mask(
    seed: u64,
    n: usize,
    tau: usize,
    round: u64,
    scratch: &mut Vec<usize>,
    mask: &mut Vec<bool>,
) {
    debug_assert!(tau <= n);
    mask.clear();
    mask.resize(n, false);
    if tau >= n {
        mask.iter_mut().for_each(|m| *m = true);
        return;
    }
    scratch.clear();
    scratch.extend(0..n);
    let mut rng = Rng::new(seed).derive(MEMBERSHIP_STREAM).derive(round);
    for k in 0..tau {
        let j = k + rng.below(n - k);
        scratch.swap(k, j);
        mask[scratch[k]] = true;
    }
}

/// The per-round client-sampling policy shared by every driver.
/// Construct with [`Participation::from_run`]; `None` means full
/// participation (today's behavior, untouched).
#[derive(Clone, Debug)]
pub struct Participation {
    seed: u64,
    n: usize,
    tau: usize,
    mask: Vec<bool>,
    scratch: Vec<usize>,
}

impl Participation {
    /// Policy for an n-shard run with cohort size `tau`. `tau ≥ n` is
    /// clamped to full participation (a strict no-op); `tau = 0` is
    /// rejected.
    pub fn new(seed: u64, n: usize, tau: usize) -> Result<Participation> {
        ensure!(n > 0, "participation needs at least one shard");
        ensure!(tau > 0, "participation tau must be >= 1 (got 0)");
        Ok(Participation {
            seed,
            n,
            tau: tau.min(n),
            mask: vec![false; n],
            scratch: Vec::new(),
        })
    }

    /// Policy from a resolved run config, or `None` when participation
    /// is off (the common case; keeps every call site a one-liner).
    pub fn from_run(participation: Option<usize>, seed: u64, n: usize) -> Result<Option<Self>> {
        match participation {
            Some(tau) => Ok(Some(Participation::new(seed, n, tau)?)),
            None => Ok(None),
        }
    }

    /// τ = n: sampling, reweighting and the epoch wire frames all
    /// short-circuit, reducing exactly to full participation.
    pub fn is_full(&self) -> bool {
        self.tau == self.n
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Cohort size τ.
    pub fn tau(&self) -> usize {
        self.tau
    }

    /// Unbiasedness weight n/τ applied to every cohort uplink.
    pub fn weight(&self) -> f64 {
        self.n as f64 / self.tau as f64
    }

    /// Draw round `round`'s cohort and return the membership mask.
    pub fn draw(&mut self, round: u64) -> &[bool] {
        let (seed, n, tau) = (self.seed, self.n, self.tau);
        cohort_mask(seed, n, tau, round, &mut self.scratch, &mut self.mask);
        &self.mask
    }

    /// The mask of the most recent [`Participation::draw`].
    pub fn mask(&self) -> &[bool] {
        &self.mask
    }
}

/// Scale a cohort member's uplink by the unbiasedness weight n/τ —
/// called identically by every driver *after* communication accounting
/// (the wire carried the unscaled values) and *before* `server.apply`.
pub fn reweight_uplink(up: &mut Uplink, w: f64) {
    for v in &mut up.delta.val {
        *v *= w;
    }
    if let Some(d2) = &mut up.delta2 {
        for v in &mut d2.val {
            *v *= w;
        }
    }
}

/// Clear a sampled-out shard's uplink slot so stale data from its last
/// participating round cannot leak into `server.apply` (slot tables are
/// reused across rounds in every driver).
pub fn clear_uplink(up: &mut Uplink) {
    up.delta.clear();
    up.delta2 = None;
}

// ---- the epoch state machine -------------------------------------------

/// Coordinator-side run phase.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MembershipState {
    /// Accepting connections until `min_clients` have joined.
    WaitingForMembers { min_clients: usize },
    /// Enough members; handshakes (dataset/state rebuilds) in flight.
    Warmup,
    /// Rounds are running under epoch `epoch`.
    RoundActive { epoch: u64 },
    /// The run loop has ended; members are being released.
    Cooldown,
}

/// One member's lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemberState {
    /// Handshake sent; state rebuild in flight.
    Joined,
    /// Live and in the sampling pool.
    Active,
    /// Live, but outside the current round's cohort (idle; heartbeats
    /// alone keep it here — no uplink is owed).
    SampledOut,
    /// Silent past the grace window; shards orphaned, awaiting a
    /// replacement or reassignment.
    Suspected,
    /// Removed from the pool (connection gone for good).
    Evicted,
}

impl MemberState {
    pub fn name(self) -> &'static str {
        match self {
            MemberState::Joined => "joined",
            MemberState::Active => "active",
            MemberState::SampledOut => "sampled_out",
            MemberState::Suspected => "suspected",
            MemberState::Evicted => "evicted",
        }
    }
}

/// Events the serve loop (and the run log / registry) consume. Emitted
/// by the transition methods; drained with [`Membership::drain_events`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MembershipEvent {
    Joined { member: u64 },
    /// A member that arrived after rounds started (first-class late
    /// join: it catches up over the snapshot/replay path and enters the
    /// sampling pool in the next epoch).
    LateJoined { member: u64 },
    SampledIn { member: u64 },
    SampledOut { member: u64 },
    Suspected { member: u64 },
    Evicted { member: u64 },
    EpochRolled { epoch: u64 },
}

impl MembershipEvent {
    /// Stable wire/run-log encoding (see `wire::runlog::RL_MEMBERSHIP`).
    pub fn kind_code(&self) -> u8 {
        match self {
            MembershipEvent::Joined { .. } => 1,
            MembershipEvent::LateJoined { .. } => 2,
            MembershipEvent::SampledIn { .. } => 3,
            MembershipEvent::SampledOut { .. } => 4,
            MembershipEvent::Suspected { .. } => 5,
            MembershipEvent::Evicted { .. } => 6,
            MembershipEvent::EpochRolled { .. } => 7,
        }
    }

    pub fn kind_name(code: u8) -> &'static str {
        match code {
            1 => "joined",
            2 => "late-joined",
            3 => "sampled-in",
            4 => "sampled-out",
            5 => "suspected",
            6 => "evicted",
            7 => "epoch-rolled",
            _ => "unknown",
        }
    }

    pub fn member(&self) -> u64 {
        match self {
            MembershipEvent::Joined { member }
            | MembershipEvent::LateJoined { member }
            | MembershipEvent::SampledIn { member }
            | MembershipEvent::SampledOut { member }
            | MembershipEvent::Suspected { member }
            | MembershipEvent::Evicted { member } => *member,
            MembershipEvent::EpochRolled { epoch } => *epoch,
        }
    }
}

/// The explicit epoch/membership state machine. Every transition either
/// succeeds (possibly emitting events) or is rejected with an error —
/// the serve loop never mutates member state directly.
#[derive(Clone, Debug)]
pub struct Membership {
    state: MembershipState,
    epoch: u64,
    members: BTreeMap<u64, MemberState>,
    events: Vec<MembershipEvent>,
}

impl Membership {
    /// A machine waiting for `min_clients` members before warmup may
    /// begin. `min_clients = 0` is normalized to 1 (a run with no
    /// members cannot round).
    pub fn new(min_clients: usize) -> Membership {
        Membership {
            state: MembershipState::WaitingForMembers {
                min_clients: min_clients.max(1),
            },
            epoch: 0,
            members: BTreeMap::new(),
            events: Vec::new(),
        }
    }

    pub fn state(&self) -> &MembershipState {
        &self.state
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn member_state(&self, id: u64) -> Option<MemberState> {
        self.members.get(&id).copied()
    }

    /// Members currently in a given state (registry gauge fodder).
    pub fn count(&self, s: MemberState) -> usize {
        self.members.values().filter(|&&m| m == s).count()
    }

    /// Drain the events emitted since the last drain, in order.
    pub fn drain_events(&mut self) -> Vec<MembershipEvent> {
        std::mem::take(&mut self.events)
    }

    fn roll_epoch(&mut self) {
        self.epoch += 1;
        let epoch = self.epoch;
        self.events.push(MembershipEvent::EpochRolled { epoch });
    }

    /// A member joins (handshake sent). Before rounds start this is a
    /// plain join; during `RoundActive` it is a **late join**: the
    /// member enters the sampling pool with the next epoch, which rolls
    /// now. Rejoining after an eviction takes the same path. A
    /// duplicate join of a live member is illegal.
    pub fn join(&mut self, id: u64) -> Result<()> {
        match self.members.get(&id) {
            Some(MemberState::Evicted) | None => {}
            Some(s) => bail!("member {id} cannot join twice (currently {})", s.name()),
        }
        match self.state {
            MembershipState::Cooldown => bail!("member {id} cannot join during cooldown"),
            MembershipState::RoundActive { .. } => {
                self.members.insert(id, MemberState::Joined);
                self.events.push(MembershipEvent::LateJoined { member: id });
                self.roll_epoch();
            }
            _ => {
                self.members.insert(id, MemberState::Joined);
                self.events.push(MembershipEvent::Joined { member: id });
            }
        }
        Ok(())
    }

    /// `WaitingForMembers → Warmup`: legal only once the member floor is
    /// met.
    pub fn warmup(&mut self) -> Result<()> {
        let MembershipState::WaitingForMembers { min_clients } = self.state else {
            bail!("warmup is only legal from WaitingForMembers (in {:?})", self.state);
        };
        ensure!(
            self.members.len() >= min_clients,
            "warmup needs {min_clients} member(s), have {}",
            self.members.len()
        );
        self.state = MembershipState::Warmup;
        Ok(())
    }

    /// A joined member finished its handshake/rebuild and is live.
    pub fn activate_member(&mut self, id: u64) -> Result<()> {
        match self.members.get(&id) {
            Some(MemberState::Joined) => {
                self.members.insert(id, MemberState::Active);
                Ok(())
            }
            Some(s) => bail!("member {id} cannot activate from {}", s.name()),
            None => bail!("member {id} cannot activate before joining"),
        }
    }

    /// `Warmup → RoundActive`: rounds may start. Rolls the first epoch.
    pub fn activate(&mut self) -> Result<()> {
        ensure!(
            self.state == MembershipState::Warmup,
            "activate is only legal from Warmup (in {:?})",
            self.state
        );
        ensure!(
            self.members.values().any(|&m| m == MemberState::Active),
            "activate needs at least one active member"
        );
        self.roll_epoch();
        self.state = MembershipState::RoundActive { epoch: self.epoch };
        Ok(())
    }

    /// Per-round sampling verdicts: members move `Active ↔ SampledOut`,
    /// emitting events only on change. Legal only while rounds run.
    /// `sampled_in` decides per member id; members in other states
    /// (Joined mid-catchup, Suspected, Evicted) are left alone.
    pub fn begin_round(&mut self, sampled_in: impl Fn(u64) -> bool) -> Result<()> {
        ensure!(
            matches!(self.state, MembershipState::RoundActive { .. }),
            "begin_round is only legal while RoundActive (in {:?})",
            self.state
        );
        let ids: Vec<u64> = self.members.keys().copied().collect();
        for id in ids {
            let cur = self.members[&id];
            let next = match (cur, sampled_in(id)) {
                (MemberState::Active, false) => MemberState::SampledOut,
                (MemberState::SampledOut, true) => MemberState::Active,
                _ => continue,
            };
            self.members.insert(id, next);
            self.events.push(match next {
                MemberState::Active => MembershipEvent::SampledIn { member: id },
                _ => MembershipEvent::SampledOut { member: id },
            });
        }
        Ok(())
    }

    /// A live member went silent past the grace window (or its socket
    /// died): its shards are orphaned pending a replacement.
    pub fn suspect(&mut self, id: u64) -> Result<()> {
        match self.members.get(&id) {
            Some(MemberState::Active) | Some(MemberState::SampledOut)
            | Some(MemberState::Joined) => {
                self.members.insert(id, MemberState::Suspected);
                self.events.push(MembershipEvent::Suspected { member: id });
                Ok(())
            }
            Some(s) => bail!("member {id} cannot be suspected from {}", s.name()),
            None => bail!("cannot suspect unknown member {id}"),
        }
    }

    /// A suspected member is removed for good. Rolls the epoch: the
    /// sampling pool's composition changed.
    pub fn evict(&mut self, id: u64) -> Result<()> {
        match self.members.get(&id) {
            Some(MemberState::Suspected) => {
                self.members.insert(id, MemberState::Evicted);
                self.events.push(MembershipEvent::Evicted { member: id });
                self.roll_epoch();
                Ok(())
            }
            Some(s) => bail!("member {id} can only be evicted while suspected (is {})", s.name()),
            None => bail!("cannot evict unknown member {id}"),
        }
    }

    /// `RoundActive → Cooldown`: the run loop ended.
    pub fn cooldown(&mut self) -> Result<()> {
        ensure!(
            matches!(self.state, MembershipState::RoundActive { .. }),
            "cooldown is only legal from RoundActive (in {:?})",
            self.state
        );
        self.state = MembershipState::Cooldown;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohort_is_pure_and_exact_size() {
        let mut s1 = Vec::new();
        let mut m1 = Vec::new();
        let mut s2 = Vec::new();
        let mut m2 = Vec::new();
        for round in [1u64, 2, 3, 100, 1_000_000] {
            cohort_mask(42, 8, 3, round, &mut s1, &mut m1);
            cohort_mask(42, 8, 3, round, &mut s2, &mut m2);
            assert_eq!(m1, m2, "round {round}: draw is not pure");
            assert_eq!(m1.iter().filter(|&&b| b).count(), 3);
        }
        // different rounds really vary (astronomically unlikely to match
        // on every one of 50 draws otherwise)
        let mut distinct = std::collections::BTreeSet::new();
        for round in 1..=50u64 {
            cohort_mask(42, 8, 3, round, &mut s1, &mut m1);
            distinct.insert(m1.clone());
        }
        assert!(distinct.len() > 1, "cohorts never vary across rounds");
    }

    #[test]
    fn tau_n_is_a_strict_noop() {
        let mut p = Participation::new(7, 4, 4).unwrap();
        assert!(p.is_full());
        assert_eq!(p.weight(), 1.0);
        assert!(p.draw(9).iter().all(|&b| b));
        // tau > n clamps to full
        assert!(Participation::new(7, 4, 9).unwrap().is_full());
        assert!(Participation::new(7, 4, 0).is_err());
    }

    #[test]
    fn sampling_is_unbiased_enough() {
        // each shard should be sampled ~ tau/n of the time
        let mut p = Participation::new(1234, 6, 2).unwrap();
        let mut hits = [0usize; 6];
        let rounds = 3000u64;
        for r in 1..=rounds {
            for (s, &b) in p.draw(r).iter().enumerate() {
                if b {
                    hits[s] += 1;
                }
            }
        }
        let expect = rounds as f64 * 2.0 / 6.0;
        for (s, &h) in hits.iter().enumerate() {
            let dev = (h as f64 - expect).abs() / expect;
            assert!(dev < 0.15, "shard {s}: {h} hits vs {expect} expected");
        }
    }

    #[test]
    fn reweight_scales_both_messages() {
        let mut up = Uplink::default();
        up.delta.push(0, 1.5);
        up.delta.push(3, -2.0);
        let mut d2 = crate::compress::SparseMsg::new();
        d2.push(1, 4.0);
        up.delta2 = Some(d2);
        reweight_uplink(&mut up, 2.0);
        assert_eq!(up.delta.val, vec![3.0, -4.0]);
        assert_eq!(up.delta2.as_ref().unwrap().val, vec![8.0]);
        clear_uplink(&mut up);
        assert_eq!(up.coords(), 0);
        assert!(up.delta2.is_none());
    }
}
