//! Run metrics: per-round residual and communication curves — the data
//! behind every figure.

use crate::util::timer::PhaseTimer;

#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// ‖x^k − x*‖² / ‖x⁰ − x*‖²  (the figures' "Residual")
    pub residual: f64,
    /// cumulative coordinates sent worker→server (all workers)
    pub coords_up: u64,
    /// cumulative bits worker→server under the *modeled* account
    /// (`coords · (float_bits + ⌈log₂ d⌉)`)
    pub bits_up: u64,
    /// cumulative coordinates sent server→workers
    pub coords_down: u64,
    /// cumulative *measured* bytes worker→server: exact encoded frame
    /// sizes (length prefix included) under the run's wire payload
    pub bytes_up: u64,
    /// cumulative *measured* bytes server→workers
    pub bytes_down: u64,
    pub wall_secs: f64,
    /// cumulative seconds spent in compute phases (worker gradient
    /// rounds + server apply) — see `util::timer::phase_bucket`
    pub compute_secs: f64,
    /// cumulative seconds spent encoding messages (downlink/uplink
    /// construction)
    pub encode_secs: f64,
    /// cumulative seconds spent on the wire (scatter/gather/poll waits)
    pub wire_secs: f64,
}

/// Cumulative communication totals, shared by every driver (the sim and
/// threaded loops, the fixed-membership distributed driver, and the
/// elastic TCP server) so their accounts cannot drift apart.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTotals {
    pub coords_up: u64,
    pub bits_up: u64,
    pub coords_down: u64,
    /// measured: exact encoded frame bytes under the configured payload
    pub bytes_up: u64,
    pub bytes_down: u64,
}

impl RoundTotals {
    pub fn accumulate(&mut self, t: &RoundTotals) {
        self.coords_up += t.coords_up;
        self.bits_up += t.bits_up;
        self.coords_down += t.coords_down;
        self.bytes_up += t.bytes_up;
        self.bytes_down += t.bytes_down;
    }
}

/// What a driver core produces besides the observed records: the metrics
/// stream itself flows through a
/// [`RoundObserver`](crate::coordinator::RoundObserver), and
/// [`RunOutcome::into_result`] reattaches whatever the collecting
/// observer gathered. [`Session`](crate::coordinator::Session) does this
/// for you.
#[derive(Debug)]
pub struct RunOutcome {
    pub method: String,
    pub final_x: Vec<f64>,
    pub rounds_run: usize,
    pub reached_target: bool,
    /// an observer's `on_round` returned
    /// [`ObserverControl::Stop`](crate::coordinator::ObserverControl)
    pub stopped_by_observer: bool,
    pub phases: PhaseTimer,
}

impl RunOutcome {
    /// Attach the collected records, producing the classic [`RunResult`].
    pub fn into_result(self, records: Vec<RoundRecord>) -> RunResult {
        RunResult {
            method: self.method,
            records,
            final_x: self.final_x,
            rounds_run: self.rounds_run,
            reached_target: self.reached_target,
            phases: self.phases,
        }
    }
}

#[derive(Debug)]
pub struct RunResult {
    pub method: String,
    pub records: Vec<RoundRecord>,
    pub final_x: Vec<f64>,
    pub rounds_run: usize,
    pub reached_target: bool,
    pub phases: PhaseTimer,
}

impl RunResult {
    /// Rounds needed to first reach `residual ≤ eps` (None if never).
    pub fn rounds_to(&self, eps: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.residual <= eps)
            .map(|r| r.round)
    }

    /// Uplink coordinates needed to first reach `residual ≤ eps`.
    pub fn coords_to(&self, eps: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.residual <= eps)
            .map(|r| r.coords_up)
    }

    /// Measured uplink bytes (exact encoded frame sizes) needed to first
    /// reach `residual ≤ eps` — the currency of the quantization-vs-
    /// sparsification comparison.
    pub fn bytes_to(&self, eps: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.residual <= eps)
            .map(|r| r.bytes_up)
    }

    /// Modeled uplink bits to first reach `residual ≤ eps`.
    pub fn bits_to(&self, eps: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.residual <= eps)
            .map(|r| r.bits_up)
    }

    pub fn final_residual(&self) -> f64 {
        self.records.last().map(|r| r.residual).unwrap_or(f64::NAN)
    }

    /// CSV rows (for `util::write_csv`).
    pub fn csv_rows(&self) -> Vec<Vec<String>> {
        self.records
            .iter()
            .map(|r| {
                vec![
                    self.method.clone(),
                    r.round.to_string(),
                    format!("{:.6e}", r.residual),
                    r.coords_up.to_string(),
                    r.bits_up.to_string(),
                    r.coords_down.to_string(),
                    r.bytes_up.to_string(),
                    r.bytes_down.to_string(),
                    format!("{:.6}", r.wall_secs),
                    format!("{:.6}", r.compute_secs),
                    format!("{:.6}", r.encode_secs),
                    format!("{:.6}", r.wire_secs),
                ]
            })
            .collect()
    }

    pub fn csv_header() -> [&'static str; 12] {
        [
            "method",
            "round",
            "residual",
            "coords_up",
            "bits_up",
            "coords_down",
            "bytes_up",
            "bytes_down",
            "wall_secs",
            "compute_secs",
            "encode_secs",
            "wire_secs",
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_with(residuals: &[f64]) -> RunResult {
        RunResult {
            method: "test".into(),
            records: residuals
                .iter()
                .enumerate()
                .map(|(i, &r)| RoundRecord {
                    round: i,
                    residual: r,
                    coords_up: (i * 10) as u64,
                    bits_up: (i * 640) as u64,
                    coords_down: (i * 100) as u64,
                    bytes_up: (i * 90) as u64,
                    bytes_down: (i * 800) as u64,
                    wall_secs: i as f64 * 0.1,
                    compute_secs: i as f64 * 0.05,
                    encode_secs: i as f64 * 0.01,
                    wire_secs: i as f64 * 0.02,
                })
                .collect(),
            final_x: vec![],
            rounds_run: residuals.len(),
            reached_target: false,
            phases: PhaseTimer::new(),
        }
    }

    #[test]
    fn rounds_to_and_coords_to() {
        let r = result_with(&[1.0, 0.5, 0.05, 0.001]);
        assert_eq!(r.rounds_to(0.1), Some(2));
        assert_eq!(r.coords_to(0.1), Some(20));
        assert_eq!(r.rounds_to(1e-9), None);
        assert_eq!(r.final_residual(), 0.001);
    }

    #[test]
    fn csv_shape() {
        let r = result_with(&[1.0, 0.1]);
        let rows = r.csv_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), RunResult::csv_header().len());
    }
}
