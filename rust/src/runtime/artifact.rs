//! AOT artifact manifest.
//!
//! `make artifacts` runs `python/compile/aot.py`, which lowers the L2 JAX
//! model (calling the L1 Pallas kernels) to **HLO text** — one module per
//! shard shape — and writes `artifacts/manifest.json` describing them:
//!
//! ```json
//! {
//!   "version": 1,
//!   "dtype": "f64",
//!   "entries": [
//!     {"kind": "grad", "m": 15, "d": 123, "file": "grad_m15_d123.hlo.txt"},
//!     {"kind": "loss", "m": 15, "d": 123, "file": "loss_m15_d123.hlo.txt"}
//!   ]
//! }
//! ```
//!
//! HLO *text* (not serialized proto) is the interchange format: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub kind: String,
    pub m: usize,
    pub d: usize,
    pub file: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dtype: String,
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let version = j.get("version").as_usize().context("manifest version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let dtype = j
            .get("dtype")
            .as_str()
            .context("manifest dtype")?
            .to_string();
        if dtype != "f64" {
            bail!("runtime expects f64 artifacts, manifest says {dtype}");
        }
        let mut entries = Vec::new();
        for e in j.get("entries").as_arr().context("manifest entries")? {
            entries.push(ArtifactEntry {
                kind: e.get("kind").as_str().context("entry kind")?.to_string(),
                m: e.get("m").as_usize().context("entry m")?,
                d: e.get("d").as_usize().context("entry d")?,
                file: dir.join(e.get("file").as_str().context("entry file")?),
            });
        }
        Ok(Manifest {
            dtype,
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Find the artifact for a given kind and shard shape.
    pub fn find(&self, kind: &str, m: usize, d: usize) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.m == m && e.d == d)
            .with_context(|| {
                format!(
                    "no '{kind}' artifact for shape m={m} d={d} in {} — \
                     re-run `make artifacts` (shapes come from python/compile/shapes.json)",
                    self.dir.display()
                )
            })
    }

    pub fn shapes(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self.entries.iter().map(|e| (e.m, e.d)).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Default artifacts directory: `$SMX_ARTIFACTS` or `artifacts/` relative
/// to the repo root / current dir.
pub fn default_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SMX_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // prefer CARGO_MANIFEST_DIR (tests/examples) then cwd
    if let Ok(root) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = Path::new(&root).join("artifacts");
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "dtype": "f64",
      "entries": [
        {"kind": "grad", "m": 15, "d": 123, "file": "grad_m15_d123.hlo.txt"},
        {"kind": "loss", "m": 15, "d": 123, "file": "loss_m15_d123.hlo.txt"},
        {"kind": "grad", "m": 30, "d": 20, "file": "grad_m30_d20.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.find("grad", 15, 123).unwrap();
        assert_eq!(e.file, Path::new("/tmp/a/grad_m15_d123.hlo.txt"));
        assert!(m.find("grad", 99, 1).is_err());
        assert_eq!(m.shapes(), vec![(15, 123), (30, 20)]);
    }

    #[test]
    fn rejects_bad_version_and_dtype() {
        assert!(Manifest::parse(r#"{"version": 2, "dtype": "f64", "entries": []}"#, Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"version": 1, "dtype": "f32", "entries": []}"#, Path::new(".")).is_err());
        assert!(Manifest::parse("not json", Path::new(".")).is_err());
    }
}
