//! PJRT gradient engine — the three-layer hot path.
//!
//! Loads the HLO-text artifact produced by `python/compile/aot.py`
//! (L2 JAX model calling the L1 Pallas kernel, lowered once at build
//! time), compiles it on the PJRT CPU client, and executes it per round.
//!
//! Artifact signatures (all f64, row-major):
//!   grad:  (x[d], a[m,d], b[m], mu[])  -> (grad[d],)
//!   loss:  (x[d], a[m,d], b[m], mu[])  -> (loss[],)
//!
//! The shard data `a`, `b` are uploaded to device buffers **once** at
//! engine construction (`execute_b` path); per round only `x` is
//! transferred. This buffer-residency optimization is part of the §Perf
//! pass (see EXPERIMENTS.md).
//!
//! Note: `xla::PjRtClient` wraps an `Rc`, so engines are not `Send`; the
//! threaded coordinator constructs each worker's engine inside its own
//! thread via an engine factory.

use crate::data::Shard;
use crate::runtime::artifact::Manifest;
use crate::runtime::GradEngine;
use anyhow::{Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

pub struct PjrtEngine {
    client: PjRtClient,
    exe_grad: PjRtLoadedExecutable,
    exe_loss: PjRtLoadedExecutable,
    /// device-resident shard data (a, b, mu) reused across rounds
    a_buf: PjRtBuffer,
    b_buf: PjRtBuffer,
    mu_buf: PjRtBuffer,
    /// host backing for the device buffers — the CPU PJRT client's
    /// host-to-device path is zero-copy, so these literals MUST outlive
    /// the buffers (dropping them is a use-after-free that manifests as
    /// shape-check aborts deep inside XLA)
    _host_literals: Vec<Literal>,
    /// reusable host staging for x (same lifetime rule)
    x_host: Vec<f64>,
    dim: usize,
    m: usize,
}

impl PjrtEngine {
    /// Build an engine for one shard, loading the matching artifacts.
    /// `client` is created internally (one per engine; cheap for CPU).
    pub fn from_shard(manifest: &Manifest, shard: &Shard, mu: f64) -> Result<PjrtEngine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Self::with_client(client, manifest, shard, mu)
    }

    pub fn with_client(
        client: PjRtClient,
        manifest: &Manifest,
        shard: &Shard,
        mu: f64,
    ) -> Result<PjrtEngine> {
        let (m, d) = (shard.num_points(), shard.dim());
        let exe_grad = compile_artifact(&client, manifest, "grad", m, d)?;
        let exe_loss = compile_artifact(&client, manifest, "loss", m, d)?;

        let a_dense = shard.a.to_dense_buffer();
        let a_lit = Literal::vec1(a_dense.as_slice())
            .reshape(&[m as i64, d as i64])
            .context("reshaping shard data literal")?;
        let b_lit = Literal::vec1(shard.b.as_slice());
        let mu_lit = Literal::scalar(mu);
        let device = client.devices().into_iter().next().context("no device")?;
        let a_buf = client
            .buffer_from_host_literal(Some(&device), &a_lit)
            .context("uploading shard matrix")?;
        let b_buf = client
            .buffer_from_host_literal(Some(&device), &b_lit)
            .context("uploading labels")?;
        let mu_buf = client
            .buffer_from_host_literal(Some(&device), &mu_lit)
            .context("uploading mu")?;

        Ok(PjrtEngine {
            client,
            exe_grad,
            exe_loss,
            a_buf,
            b_buf,
            mu_buf,
            _host_literals: vec![a_lit, b_lit, mu_lit],
            x_host: vec![0.0; d],
            dim: d,
            m,
        })
    }

    pub fn num_points(&self) -> usize {
        self.m
    }

    fn run1(&mut self, grad: bool, x: &[f64]) -> Result<Literal> {
        // stage x into engine-owned memory (zero-copy transfer: the host
        // slice must stay valid until execution completes)
        self.x_host.copy_from_slice(x);
        let device = self.client.devices().into_iter().next().context("no device")?;
        let x_buf = self
            .client
            .buffer_from_host_buffer(self.x_host.as_slice(), &[self.dim], Some(&device))
            .context("uploading x")?;
        let exe = if grad { &self.exe_grad } else { &self.exe_loss };
        let outs = exe
            .execute_b(&[&x_buf, &self.a_buf, &self.b_buf, &self.mu_buf])
            .context("executing artifact")?;
        let lit = outs[0][0].to_literal_sync().context("fetching result")?;
        lit.to_tuple1().context("unwrapping 1-tuple result")
    }
}

fn compile_artifact(
    client: &PjRtClient,
    manifest: &Manifest,
    kind: &str,
    m: usize,
    d: usize,
) -> Result<PjRtLoadedExecutable> {
    let entry = manifest.find(kind, m, d)?;
    let proto = xla::HloModuleProto::from_text_file(
        entry
            .file
            .to_str()
            .context("artifact path not valid UTF-8")?,
    )
    .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", entry.file.display()))
}

impl GradEngine for PjrtEngine {
    fn grad_into(&mut self, x: &[f64], out: &mut [f64]) {
        let lit = self.run1(true, x).expect("pjrt grad execution failed");
        lit.copy_raw_to(out).expect("copying grad result");
    }

    fn loss(&mut self, x: &[f64]) -> f64 {
        let lit = self.run1(false, x).expect("pjrt loss execution failed");
        lit.to_vec::<f64>().expect("reading loss result")[0]
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// PJRT engine integration tests live in `tests/parity.rs` (they need the
// artifacts built by `make artifacts`).
