//! Runtime engines: how a worker evaluates its local gradient ∇f_i(x).
//!
//! * [`native::NativeEngine`] — pure-Rust CSR evaluation (reference and
//!   default for large sweep experiments);
//! * [`pjrt::PjrtEngine`] — executes the AOT-compiled JAX/Pallas artifact
//!   (`artifacts/*.hlo.txt`, produced by `make artifacts`) through the
//!   PJRT CPU client (`xla` crate). This is the paper's three-layer hot
//!   path: Python never runs at request time.
//!
//! Engines are cross-validated against each other in `tests/parity.rs`.

pub mod artifact;
pub mod native;
pub mod pjrt;

/// A worker's gradient oracle.
///
/// Deliberately *not* `Send`: the PJRT client wraps an `Rc`, so the
/// threaded coordinator constructs each worker's engine inside its own
/// thread (see [`crate::coordinator::EngineFactory`]).
pub trait GradEngine {
    /// out = ∇f_i(x)
    fn grad_into(&mut self, x: &[f64], out: &mut [f64]);

    /// f_i(x) (used by metrics / loss curves, not on the optimizer path)
    fn loss(&mut self, x: &[f64]) -> f64;

    fn dim(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Engine selection for experiment configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
        }
    }
}
