//! Runtime engines: how a worker evaluates its local gradient ∇f_i(x).
//!
//! * [`native::NativeEngine`] — pure-Rust CSR evaluation (reference and
//!   default for large sweep experiments);
//! * [`pjrt::PjrtEngine`] — executes the AOT-compiled JAX/Pallas artifact
//!   (`artifacts/*.hlo.txt`, produced by `make artifacts`) through the
//!   PJRT CPU client (`xla` crate). This is the paper's three-layer hot
//!   path: Python never runs at request time.
//!
//! Engines are cross-validated against each other in `tests/parity.rs`.

pub mod artifact;
pub mod native;

#[cfg(feature = "pjrt")]
pub mod pjrt;

/// Stub PJRT engine for builds without the `pjrt` feature (the offline
/// image has no `xla` crate). Constructors fail at runtime with a clear
/// message; every call site compiles unchanged.
#[cfg(not(feature = "pjrt"))]
pub mod pjrt {
    use crate::data::Shard;
    use crate::runtime::artifact::Manifest;
    use crate::runtime::GradEngine;
    use anyhow::{bail, Result};

    pub struct PjrtEngine {
        _private: (),
    }

    impl PjrtEngine {
        pub fn from_shard(_manifest: &Manifest, _shard: &Shard, _mu: f64) -> Result<PjrtEngine> {
            bail!("smx was built without the `pjrt` feature; rebuild with `--features pjrt` (requires the xla crate)")
        }
    }

    impl GradEngine for PjrtEngine {
        fn grad_into(&mut self, _x: &[f64], _out: &mut [f64]) {
            unreachable!("pjrt stub cannot be constructed")
        }

        fn loss(&mut self, _x: &[f64]) -> f64 {
            unreachable!("pjrt stub cannot be constructed")
        }

        fn dim(&self) -> usize {
            unreachable!("pjrt stub cannot be constructed")
        }

        fn name(&self) -> &'static str {
            "pjrt-stub"
        }
    }
}

/// A worker's gradient oracle.
///
/// Deliberately *not* `Send`: the PJRT client wraps an `Rc`, so the
/// threaded coordinator constructs each worker's engine inside its own
/// thread (see [`crate::coordinator::EngineFactory`]).
pub trait GradEngine {
    /// out = ∇f_i(x)
    fn grad_into(&mut self, x: &[f64], out: &mut [f64]);

    /// f_i(x) (used by metrics / loss curves, not on the optimizer path)
    fn loss(&mut self, x: &[f64]) -> f64;

    fn dim(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Engine selection for experiment configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
        }
    }
}
