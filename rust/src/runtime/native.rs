//! Native (pure-Rust) gradient engine: wraps [`LogReg`] as a
//! [`GradEngine`]. This is the reference oracle for PJRT parity tests and
//! the default engine for the large figure sweeps, where millions of
//! rounds make per-call PJRT literal marshalling the dominant cost.

use crate::data::Shard;
use crate::objective::logreg::LogReg;
use crate::runtime::GradEngine;

pub struct NativeEngine {
    pub obj: LogReg,
}

impl NativeEngine {
    pub fn new(obj: LogReg) -> NativeEngine {
        NativeEngine { obj }
    }

    pub fn from_shard(s: &Shard, mu: f64) -> NativeEngine {
        NativeEngine::new(LogReg::from_shard(s, mu))
    }
}

impl GradEngine for NativeEngine {
    fn grad_into(&mut self, x: &[f64], out: &mut [f64]) {
        self.obj.grad_into(x, out);
    }

    fn loss(&mut self, x: &[f64]) -> f64 {
        self.obj.loss(x)
    }

    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn engine_delegates() {
        let ds = synth::generate(&synth::tiny_spec(), 1);
        let (_, shards) = ds.prepare(3, 1);
        let mut e = NativeEngine::from_shard(&shards[0], 1e-3);
        let x = vec![0.1; e.dim()];
        let mut g = vec![0.0; e.dim()];
        e.grad_into(&x, &mut g);
        let direct = LogReg::from_shard(&shards[0], 1e-3).grad(&x);
        assert_eq!(g, direct);
        assert_eq!(e.name(), "native");
        assert!(e.loss(&x).is_finite());
    }
}
