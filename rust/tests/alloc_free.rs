//! §Perf acceptance: the round pipeline performs **zero heap allocations
//! per round in steady state** for the matrix-aware methods.
//!
//! A thread-local counting allocator (const-initialized TLS, so the
//! allocator itself never recurses) tallies every alloc/realloc made by
//! the *calling* thread. Per-thread counting keeps the assertions immune
//! to the libtest harness and to sibling tests running concurrently, and
//! for the threaded driver it scopes the measurement to the coordinator
//! thread (worker threads own their engines and are steady-state-free by
//! the same sync_round argument).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn tl_count() -> u64 {
    TL_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

fn tl_bump() {
    // try_with: allocations during TLS teardown must not panic inside
    // the allocator
    let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        tl_bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        tl_bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        tl_bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use smx::coordinator::{Driver, EngineFactory, RunConfig, Session};
use smx::data::synth;
use smx::methods::{build, sync_round, Method, MethodSpec, RoundBuffers};
use smx::objective::Smoothness;
use smx::runtime::native::NativeEngine;
use smx::runtime::GradEngine;
use smx::sampling::SamplingKind;
use smx::util::rng::Rng;
use std::sync::Arc;

fn setup() -> (Vec<smx::data::Shard>, Smoothness) {
    let ds = synth::generate(&synth::tiny_spec(), 3);
    let (_, shards) = ds.prepare(4, 3);
    let sm = Smoothness::build(&shards, 1e-3);
    (shards, sm)
}

fn engines(shards: &[smx::data::Shard]) -> Vec<Box<dyn GradEngine>> {
    shards
        .iter()
        .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
        .collect()
}

fn method(name: &str, sm: &Smoothness) -> Method {
    let spec = MethodSpec::new(name, 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
    build(&spec, sm).unwrap()
}

fn spec(name: &str, sm: &Smoothness) -> MethodSpec {
    MethodSpec::new(name, 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim])
}

/// The core claim: after warmup (plus reserving the worst-case sketch
/// capacity), `sync_round` makes literally zero allocator calls.
#[test]
fn sync_round_steady_state_is_allocation_free() {
    let (shards, sm) = setup();
    let dim = sm.dim;
    for name in ["dcgd+", "diana+"] {
        let mut m = method(name, &sm);
        let mut eng = engines(&shards);
        let base = Rng::new(99);
        let mut server_rng = base.derive(u64::MAX);
        let mut worker_rngs: Vec<Rng> = (0..shards.len()).map(|i| base.derive(i as u64)).collect();
        let mut bufs = RoundBuffers::new(shards.len());

        for _ in 0..60 {
            sync_round(&mut m, &mut eng, &mut server_rng, &mut worker_rngs, &mut bufs);
        }
        // a Bernoulli sketch can select up to all d coordinates
        for up in &mut bufs.ups {
            up.delta.idx.reserve(dim);
            up.delta.val.reserve(dim);
        }

        let before = tl_count();
        for _ in 0..100 {
            sync_round(&mut m, &mut eng, &mut server_rng, &mut worker_rngs, &mut bufs);
        }
        let delta = tl_count() - before;
        assert_eq!(
            delta, 0,
            "{name}: {delta} allocations in 100 steady-state rounds (want 0)"
        );
    }
}

/// The sim driver end-to-end *through the `Session` front door*: doubling
/// the round count must not add allocations beyond (identical) setup +
/// warmup — i.e. the per-round marginal allocation count is zero, builder
/// and observer seam included.
#[test]
fn run_sim_marginal_allocations_are_zero() {
    let (shards, sm) = setup();

    let measure = |rounds: usize| -> u64 {
        let cfg = RunConfig {
            max_rounds: rounds,
            record_every: 1,
            seed: 0xA110C,
            ..Default::default()
        };
        let x_star = vec![0.0; sm.dim];
        let before = tl_count();
        let r = Session::new(spec("diana+", &sm))
            .smoothness(&sm)
            .x_star(&x_star)
            .engines(engines(&shards))
            .run_config(cfg)
            .run()
            .unwrap();
        assert_eq!(r.rounds_run, rounds);
        tl_count() - before
    };

    // warm up caches/lazy inits once so both measured runs see the same
    // environment
    measure(10);
    let a = measure(150);
    let b = measure(300);
    // identical setup; rounds 151..300 must be allocation-free (modulo a
    // couple of deterministic capacity-doubling events in the sketch
    // buffers, which amortize to zero)
    let marginal = b.saturating_sub(a);
    assert!(
        marginal <= 2,
        "run_sim allocated {marginal} times across 150 extra rounds (want ~0)"
    );
}

/// The threaded driver's coordinator thread is now **literally
/// allocation-free** per round: the SPSC ring buffers replaced mpsc's
/// per-send block allocation (the last §Perf backlog source), uplink
/// buffers recycle server→worker, and workers drop their downlink `Arc`
/// clone before their uplink send so the in-place `Arc::get_mut` rewrite
/// always succeeds. Worker-thread allocations don't count here (the
/// counter is thread-local); they are steady-state-free by the same
/// sync_round argument.
#[test]
fn run_threaded_coordinator_is_allocation_free() {
    let (shards, sm) = setup();

    let measure = |rounds: usize| -> u64 {
        let shards2 = shards.clone();
        let factory: EngineFactory = Arc::new(move |i| {
            Box::new(NativeEngine::from_shard(&shards2[i], 1e-3)) as Box<dyn GradEngine>
        });
        let cfg = RunConfig {
            max_rounds: rounds,
            record_every: 1,
            seed: 0xA110C,
            ..Default::default()
        };
        let x_star = vec![0.0; sm.dim];
        let before = tl_count();
        let r = Session::new(spec("dcgd+", &sm))
            .smoothness(&sm)
            .x_star(&x_star)
            .driver(Driver::Threaded)
            .engine_factory(factory)
            .run_config(cfg)
            .run()
            .unwrap();
        assert_eq!(r.rounds_run, rounds);
        tl_count() - before
    };

    measure(10);
    let a = measure(100);
    let b = measure(300);
    // 200 extra rounds must add nothing: ring send/recv move values
    // through preallocated slots, records are pushed within capacity, and
    // the downlink Arc is rewritten in place every round
    let marginal = b.saturating_sub(a);
    assert_eq!(
        marginal, 0,
        "threaded coordinator allocated {marginal} times across 200 extra \
         rounds (want 0 — did a ring fall back to an allocating path?)"
    );
}

/// Bitwise invariant guard: with the buffer-reusing pipeline in place,
/// the sim and threaded drivers still produce identical trajectories.
#[test]
fn drivers_still_bitwise_identical_with_buffer_reuse() {
    let (shards, sm) = setup();
    let cfg = RunConfig {
        max_rounds: 40,
        ..Default::default()
    };
    let x_star = vec![0.0; sm.dim];

    let r1 = Session::new(spec("diana+", &sm))
        .smoothness(&sm)
        .x_star(&x_star)
        .engines(engines(&shards))
        .run_config(cfg.clone())
        .run()
        .unwrap();

    let shards2 = shards.clone();
    let factory: EngineFactory = Arc::new(move |i| {
        Box::new(NativeEngine::from_shard(&shards2[i], 1e-3)) as Box<dyn GradEngine>
    });
    let r2 = Session::new(spec("diana+", &sm))
        .smoothness(&sm)
        .x_star(&x_star)
        .driver(Driver::Threaded)
        .engine_factory(factory)
        .run_config(cfg)
        .run()
        .unwrap();

    assert_eq!(r1.final_x, r2.final_x);
    assert_eq!(
        r1.records.last().unwrap().coords_up,
        r2.records.last().unwrap().coords_up
    );
}
