//! Property-based tests on coordinator/protocol invariants (mini-prop
//! harness; see `util::prop`): routing/accounting/state invariants that
//! must hold for every method, sampling, τ, and seed.

use smx::compress::{MatrixAware, SparseMsg};
use smx::config::ExperimentConfig;
use smx::coordinator::{RunConfig, Session};
use smx::data::synth;
use smx::experiments::runner;
use smx::linalg::psd::PsdRoot;
use smx::methods::{MethodSpec, METHOD_NAMES};
use smx::objective::Smoothness;
use smx::prop_assert;
use smx::sampling::{IndependentSampling, SamplingKind};
use smx::util::prop::{check, forall, PropConfig};
use smx::util::rng::Rng;

fn random_spec(rng: &mut Rng, dim: usize) -> (String, SamplingKind, f64) {
    let method = METHOD_NAMES[rng.below(METHOD_NAMES.len())].to_string();
    let sampling = match rng.below(4) {
        0 => SamplingKind::Uniform,
        1 => SamplingKind::ImportanceDcgd,
        2 => SamplingKind::ImportanceDiana,
        _ => SamplingKind::ImportanceAdiana,
    };
    let tau = 1.0 + rng.below(dim.min(8)) as f64;
    (method, sampling, tau)
}

#[test]
fn prop_every_method_makes_progress_and_accounts_consistently() {
    // shared setup (expensive) outside the property loop
    let cfg = ExperimentConfig {
        dataset: "tiny".into(),
        workers: 4,
        ..Default::default()
    };
    let prep = runner::prepare_with(&cfg, true).unwrap();
    let dim = prep.sm.dim;

    forall(
        PropConfig {
            cases: 24,
            base_seed: 0xAB,
        },
        "method progress + accounting",
        |rng| {
            let (method_name, sampling, tau) = random_spec(rng, dim);
            let spec = MethodSpec::new(&method_name, tau, sampling, cfg.mu, vec![0.0; dim]);
            let rounds = 120;
            let run_cfg = RunConfig {
                max_rounds: rounds,
                record_every: 1,
                seed: rng.next_u64(),
                ..Default::default()
            };
            let r = Session::new(spec)
                .prepared(&prep)
                .run_config(run_cfg)
                .run()
                .unwrap();

            // residual decreased from 1.0
            prop_assert!(
                r.final_residual() < 1.0,
                "{method_name} ({sampling:?}, tau={tau}) made no progress: {:.3e}",
                r.final_residual()
            );
            // iterate is finite
            prop_assert!(
                r.final_x.iter().all(|v| v.is_finite()),
                "{method_name} produced non-finite iterate"
            );
            // accounting monotone and consistent with τ
            let mut prev = 0u64;
            for rec in &r.records {
                prop_assert!(rec.coords_up >= prev, "coords_up not monotone");
                prev = rec.coords_up;
            }
            let last = r.records.last().unwrap();
            let per_round_worker =
                last.coords_up as f64 / (rounds as f64 * prep.sm.n() as f64);
            let factor = if method_name.starts_with("adiana") { 2.0 } else { 1.0 };
            let expected = if method_name == "dgd" {
                dim as f64
            } else {
                tau * factor
            };
            prop_assert!(
                (per_round_worker - expected).abs() <= 0.5 * expected + 0.5,
                "{method_name} tau={tau}: {per_round_worker:.2} coords/round/worker vs expected {expected}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_matrix_aware_unbiasedness_random_roots() {
    check("matrix-aware compressor unbiased for random PSD roots", |rng| {
        let d = 3 + rng.below(6);
        // random PSD with ridge
        let mut b = smx::linalg::Mat::zeros(d + 2, d);
        for r in 0..d + 2 {
            for c in 0..d {
                b[(r, c)] = rng.normal();
            }
        }
        let mut l = b.gram();
        l.scale(0.3);
        l.add_diag(0.01 + rng.uniform() * 0.1);
        let root = PsdRoot::from_dense(&l);

        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.2, 1.0)).collect();
        let mut ma = MatrixAware::new(IndependentSampling::new(p));
        let trials = 20_000;
        let mut mean = vec![0.0; d];
        let mut msg = SparseMsg::new();
        let mut g = vec![0.0; d];
        for _ in 0..trials {
            ma.compress(&root, &x, rng, &mut msg);
            MatrixAware::decompress_into(&root, &msg, &mut g);
            for j in 0..d {
                mean[j] += g[j];
            }
        }
        for j in 0..d {
            let m = mean[j] / trials as f64;
            prop_assert!(
                (m - x[j]).abs() < 0.12 * (1.0 + x[j].abs()),
                "biased at coord {j}: E[g]={m} x={}",
                x[j]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_water_filling_budget_invariant() {
    check("water-filling probabilities meet the τ budget", |rng| {
        let d = 2 + rng.below(40);
        let diag: Vec<f64> = (0..d)
            .map(|_| 1e-4 + rng.uniform() * rng.uniform() * 3.0)
            .collect();
        let tau = 1.0 + rng.below(d) as f64;
        for kind in [
            SamplingKind::ImportanceDcgd,
            SamplingKind::ImportanceDiana,
            SamplingKind::ImportanceAdiana,
        ] {
            let s = kind.build(&diag, tau, 1e-3, 1 + rng.below(20));
            let sum = s.expected_size();
            prop_assert!(
                (sum - tau).abs() < 1e-6 * tau,
                "{kind:?}: Σp = {sum} ≠ τ = {tau} (d={d})"
            );
            prop_assert!(
                s.p.iter().all(|&p| p > 0.0 && p <= 1.0),
                "{kind:?}: improper probabilities"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_smoothness_invariants_random_shards() {
    forall(
        PropConfig {
            cases: 10,
            base_seed: 3,
        },
        "smoothness constants ordering",
        |rng| {
            let spec = synth::SynthSpec {
                name: "prop",
                points: 40 + rng.below(80),
                d: 5 + rng.below(25),
                n: 2 + rng.below(4),
                nnz_per_row: 3 + rng.below(5),
                scale_alpha: rng.uniform_in(0.3, 1.5),
                noise: 0.05,
            };
            let ds = synth::generate(&spec, rng.next_u64());
            let n = spec.n;
            let (_, shards) = ds.prepare(n, rng.next_u64());
            let sm = Smoothness::build(&shards, 1e-3);
            // μ ≤ L ≤ (1/n)ΣL_i ≤ L_max; diag ≤ λ_max per worker
            prop_assert!(sm.l >= sm.mu * 0.999, "L < mu");
            let avg = sm.locals.iter().map(|l| l.l_i).sum::<f64>() / sm.n() as f64;
            prop_assert!(sm.l <= avg * 1.0001, "L={} > avg={avg}", sm.l);
            prop_assert!(sm.l_max <= sm.l * sm.n() as f64 * 1.0001, "L_max > nL");
            for loc in &sm.locals {
                let dmax = loc.diag.iter().cloned().fold(0.0, f64::max);
                prop_assert!(dmax <= loc.l_i * 1.0001, "diag > λmax");
            }
            // ν, ν_s in their ranges (eq. 14)
            let nu = sm.nu();
            prop_assert!(nu >= 0.999 && nu <= sm.n() as f64 * 1.0001, "nu={nu}");
            Ok(())
        },
    );
}

#[test]
fn prop_downlink_coords_match_method_class() {
    let cfg = ExperimentConfig {
        dataset: "tiny".into(),
        workers: 3,
        ..Default::default()
    };
    let prep = runner::prepare_with(&cfg, true).unwrap();
    let dim = prep.sm.dim;
    forall(
        PropConfig {
            cases: 8,
            base_seed: 9,
        },
        "downlink accounting",
        |rng| {
            let (method_name, sampling, tau) = random_spec(rng, dim);
            let spec = MethodSpec::new(&method_name, tau, sampling, cfg.mu, vec![0.0; dim]);
            let rounds = 40;
            let run_cfg = RunConfig {
                max_rounds: rounds,
                record_every: rounds,
                seed: rng.next_u64(),
                ..Default::default()
            };
            let r = Session::new(spec)
                .prepared(&prep)
                .run_config(run_cfg)
                .run()
                .unwrap();
            let down = r.records.last().unwrap().coords_down as f64
                / (rounds as f64 * prep.sm.n() as f64);
            match method_name.as_str() {
                "adiana" | "adiana+" => {
                    prop_assert!((down - 2.0 * dim as f64).abs() < 1e-9, "adiana downlink {down}")
                }
                "diana++" => prop_assert!(
                    down < dim as f64,
                    "diana++ downlink should be sparse on average: {down}"
                ),
                _ => prop_assert!((down - dim as f64).abs() < 1e-9, "dense downlink {down}"),
            }
            Ok(())
        },
    );
}
