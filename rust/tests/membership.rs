//! Table-driven transition tests for the membership subsystem
//! (`coordinator::membership`): every legal edge of the epoch state
//! machine `WaitingForMembers → Warmup → RoundActive → Cooldown`, the
//! member lifecycle `Joined → Active ↔ SampledOut → Suspected →
//! Evicted`, and — just as important — the illegal transitions the
//! machine must *reject* instead of silently absorbing. Each case is a
//! script of operations against a fresh machine plus the expected
//! verdict of the final op and assertions on the resulting state,
//! epoch, and drained event stream.

use anyhow::Result;
use smx::coordinator::membership::{
    Membership, MemberState, MembershipEvent, MembershipState,
};

/// One scripted operation against the machine. `BeginRound` carries the
/// member ids sampled into the round's cohort.
#[derive(Clone, Debug)]
enum Op {
    Join(u64),
    Warmup,
    ActivateMember(u64),
    Activate,
    BeginRound(Vec<u64>),
    Suspect(u64),
    Evict(u64),
    Cooldown,
}

fn apply(m: &mut Membership, op: &Op) -> Result<()> {
    match op {
        Op::Join(id) => m.join(*id),
        Op::Warmup => m.warmup(),
        Op::ActivateMember(id) => m.activate_member(*id),
        Op::Activate => m.activate(),
        Op::BeginRound(cohort) => m.begin_round(|id| cohort.contains(&id)),
        Op::Suspect(id) => m.suspect(*id),
        Op::Evict(id) => m.evict(*id),
        Op::Cooldown => m.cooldown(),
    }
}

/// Drive `setup` (every op must succeed), then apply `last` and return
/// the machine plus the final op's verdict.
fn run_script(min_clients: usize, setup: &[Op], last: &Op) -> (Membership, Result<()>) {
    let mut m = Membership::new(min_clients);
    for (i, op) in setup.iter().enumerate() {
        apply(&mut m, op).unwrap_or_else(|e| panic!("setup op {i} ({op:?}) failed: {e:#}"));
    }
    let verdict = apply(&mut m, last);
    (m, verdict)
}

/// Standard prefix: two members joined, warmed up, activated, rounds
/// running under epoch 1.
fn live_pair() -> Vec<Op> {
    vec![
        Op::Join(0),
        Op::Join(1),
        Op::Warmup,
        Op::ActivateMember(0),
        Op::ActivateMember(1),
        Op::Activate,
    ]
}

#[test]
fn legal_transitions_drive_the_full_lifecycle() {
    struct Case {
        name: &'static str,
        min_clients: usize,
        setup: Vec<Op>,
        last: Op,
        // (state, epoch, member, member_state) expectations after `last`
        state: MembershipState,
        epoch: u64,
        member: Option<(u64, MemberState)>,
    }
    let cases = [
        Case {
            name: "join before rounds is a plain join",
            min_clients: 2,
            setup: vec![],
            last: Op::Join(0),
            state: MembershipState::WaitingForMembers { min_clients: 2 },
            epoch: 0,
            member: Some((0, MemberState::Joined)),
        },
        Case {
            name: "warmup once the floor is met",
            min_clients: 2,
            setup: vec![Op::Join(0), Op::Join(1)],
            last: Op::Warmup,
            state: MembershipState::Warmup,
            epoch: 0,
            member: Some((0, MemberState::Joined)),
        },
        Case {
            name: "activate rolls the first epoch",
            min_clients: 2,
            setup: vec![Op::Join(0), Op::Join(1), Op::Warmup, Op::ActivateMember(0)],
            last: Op::Activate,
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 1,
            member: Some((0, MemberState::Active)),
        },
        Case {
            name: "begin_round samples a member out",
            min_clients: 2,
            setup: live_pair(),
            last: Op::BeginRound(vec![0]),
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 1,
            member: Some((1, MemberState::SampledOut)),
        },
        Case {
            name: "begin_round samples a member back in",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::BeginRound(vec![0]));
                s
            },
            last: Op::BeginRound(vec![1]),
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 1,
            member: Some((1, MemberState::Active)),
        },
        Case {
            name: "late join during rounds rolls the epoch",
            min_clients: 2,
            setup: live_pair(),
            last: Op::Join(7),
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 2,
            member: Some((7, MemberState::Joined)),
        },
        Case {
            name: "suspect orphans a live member",
            min_clients: 2,
            setup: live_pair(),
            last: Op::Suspect(1),
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 1,
            member: Some((1, MemberState::Suspected)),
        },
        Case {
            name: "suspect works on a sampled-out member",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::BeginRound(vec![0]));
                s
            },
            last: Op::Suspect(1),
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 1,
            member: Some((1, MemberState::Suspected)),
        },
        Case {
            name: "evict removes a suspect and rolls the epoch",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::Suspect(1));
                s
            },
            last: Op::Evict(1),
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 2,
            member: Some((1, MemberState::Evicted)),
        },
        Case {
            name: "an evicted member may rejoin (as a late join)",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::Suspect(1));
                s.push(Op::Evict(1));
                s
            },
            last: Op::Join(1),
            state: MembershipState::RoundActive { epoch: 1 },
            epoch: 3,
            member: Some((1, MemberState::Joined)),
        },
        Case {
            name: "cooldown ends the run loop",
            min_clients: 2,
            setup: live_pair(),
            last: Op::Cooldown,
            state: MembershipState::Cooldown,
            epoch: 1,
            member: None,
        },
    ];
    for c in cases {
        let (m, verdict) = run_script(c.min_clients, &c.setup, &c.last);
        verdict.unwrap_or_else(|e| panic!("{}: expected success, got: {e:#}", c.name));
        assert_eq!(*m.state(), c.state, "{}: final machine state", c.name);
        assert_eq!(m.epoch(), c.epoch, "{}: epoch", c.name);
        if let Some((id, want)) = c.member {
            assert_eq!(
                m.member_state(id),
                Some(want),
                "{}: member {id} state",
                c.name
            );
        }
    }
}

#[test]
fn illegal_transitions_are_rejected() {
    struct Case {
        name: &'static str,
        min_clients: usize,
        setup: Vec<Op>,
        last: Op,
    }
    let cases = [
        Case {
            name: "warmup below the member floor",
            min_clients: 2,
            setup: vec![Op::Join(0)],
            last: Op::Warmup,
        },
        Case {
            name: "warmup twice",
            min_clients: 1,
            setup: vec![Op::Join(0), Op::Warmup],
            last: Op::Warmup,
        },
        Case {
            name: "activate without warmup",
            min_clients: 1,
            setup: vec![Op::Join(0)],
            last: Op::Activate,
        },
        Case {
            name: "activate with no active member",
            min_clients: 1,
            setup: vec![Op::Join(0), Op::Warmup],
            last: Op::Activate,
        },
        Case {
            name: "duplicate join of a live member",
            min_clients: 2,
            setup: vec![Op::Join(0)],
            last: Op::Join(0),
        },
        Case {
            name: "join during cooldown",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::Cooldown);
                s
            },
            last: Op::Join(9),
        },
        Case {
            name: "begin_round before rounds start",
            min_clients: 2,
            setup: vec![Op::Join(0), Op::Join(1), Op::Warmup],
            last: Op::BeginRound(vec![0]),
        },
        Case {
            name: "begin_round after cooldown",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::Cooldown);
                s
            },
            last: Op::BeginRound(vec![0]),
        },
        Case {
            name: "activate_member before joining",
            min_clients: 1,
            setup: vec![Op::Join(0), Op::Warmup],
            last: Op::ActivateMember(5),
        },
        Case {
            name: "activate_member twice",
            min_clients: 1,
            setup: vec![Op::Join(0), Op::Warmup, Op::ActivateMember(0)],
            last: Op::ActivateMember(0),
        },
        Case {
            name: "suspect an unknown member",
            min_clients: 2,
            setup: live_pair(),
            last: Op::Suspect(42),
        },
        Case {
            name: "suspect an evicted member",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::Suspect(1));
                s.push(Op::Evict(1));
                s
            },
            last: Op::Suspect(1),
        },
        Case {
            name: "evict without a prior suspect",
            min_clients: 2,
            setup: live_pair(),
            last: Op::Evict(1),
        },
        Case {
            name: "evict an unknown member",
            min_clients: 2,
            setup: live_pair(),
            last: Op::Evict(42),
        },
        Case {
            name: "cooldown before rounds start",
            min_clients: 2,
            setup: vec![Op::Join(0), Op::Join(1), Op::Warmup],
            last: Op::Cooldown,
        },
        Case {
            name: "cooldown twice",
            min_clients: 2,
            setup: {
                let mut s = live_pair();
                s.push(Op::Cooldown);
                s
            },
            last: Op::Cooldown,
        },
    ];
    for c in cases {
        let before = {
            let mut m = Membership::new(c.min_clients);
            for (i, op) in c.setup.iter().enumerate() {
                apply(&mut m, op)
                    .unwrap_or_else(|e| panic!("{}: setup op {i} ({op:?}) failed: {e:#}", c.name));
            }
            m.drain_events();
            m
        };
        let mut m = before.clone();
        let verdict = apply(&mut m, &c.last);
        assert!(verdict.is_err(), "{}: expected rejection, got success", c.name);
        // a rejected transition must leave the machine untouched: same
        // phase, same epoch, same member table, and no stray events
        assert_eq!(m.state(), before.state(), "{}: state changed on rejection", c.name);
        assert_eq!(m.epoch(), before.epoch(), "{}: epoch rolled on rejection", c.name);
        for id in 0..10u64 {
            assert_eq!(
                m.member_state(id),
                before.member_state(id),
                "{}: member {id} moved on rejection",
                c.name
            );
        }
        assert!(
            m.drain_events().is_empty(),
            "{}: rejected transition emitted events",
            c.name
        );
    }
}

#[test]
fn event_stream_narrates_the_lifecycle_in_order() {
    let mut m = Membership::new(2);
    m.join(0).unwrap();
    m.join(1).unwrap();
    m.warmup().unwrap();
    m.activate_member(0).unwrap();
    m.activate_member(1).unwrap();
    m.activate().unwrap();
    m.begin_round(|id| id == 0).unwrap(); // member 1 sampled out
    m.begin_round(|id| id == 1).unwrap(); // and back in; 0 out
    m.begin_round(|id| id == 1).unwrap(); // no change: no events
    m.suspect(0).unwrap();
    m.evict(0).unwrap();
    m.join(2).unwrap(); // late join
    m.cooldown().unwrap();

    let events = m.drain_events();
    use MembershipEvent as E;
    assert_eq!(
        events,
        vec![
            E::Joined { member: 0 },
            E::Joined { member: 1 },
            E::EpochRolled { epoch: 1 },
            E::SampledOut { member: 1 },
            E::SampledIn { member: 1 },
            E::SampledOut { member: 0 },
            E::Suspected { member: 0 },
            E::Evicted { member: 0 },
            E::EpochRolled { epoch: 2 },
            E::LateJoined { member: 2 },
            E::EpochRolled { epoch: 3 },
        ]
    );
    // the drain is a take: a second drain is empty
    assert!(m.drain_events().is_empty());
    // kind codes are a total, stable mapping (run-log encoding)
    for ev in [
        E::Joined { member: 0 },
        E::LateJoined { member: 0 },
        E::SampledIn { member: 0 },
        E::SampledOut { member: 0 },
        E::Suspected { member: 0 },
        E::Evicted { member: 0 },
        E::EpochRolled { epoch: 1 },
    ] {
        let code = ev.kind_code();
        assert!((1..=7).contains(&code), "{ev:?}: code {code} out of range");
        assert_ne!(E::kind_name(code), "unknown", "{ev:?}: unnamed code");
    }
}

#[test]
fn min_clients_zero_normalizes_to_one() {
    let mut m = Membership::new(0);
    assert_eq!(
        *m.state(),
        MembershipState::WaitingForMembers { min_clients: 1 }
    );
    assert!(m.warmup().is_err(), "warmup with zero members must fail");
    m.join(0).unwrap();
    m.warmup().unwrap();
}

#[test]
fn counts_track_member_states() {
    let mut m = Membership::new(2);
    m.join(0).unwrap();
    m.join(1).unwrap();
    m.join(2).unwrap();
    m.warmup().unwrap();
    m.activate_member(0).unwrap();
    m.activate_member(1).unwrap();
    assert_eq!(m.count(MemberState::Joined), 1);
    assert_eq!(m.count(MemberState::Active), 2);
    m.activate().unwrap();
    m.begin_round(|id| id == 0).unwrap();
    assert_eq!(m.count(MemberState::Active), 1);
    assert_eq!(m.count(MemberState::SampledOut), 1);
    // Joined (mid-catchup) members are untouched by sampling verdicts
    assert_eq!(m.count(MemberState::Joined), 1);
    m.suspect(1).unwrap();
    m.evict(1).unwrap();
    assert_eq!(m.count(MemberState::Suspected), 0);
    assert_eq!(m.count(MemberState::Evicted), 1);
}
