//! Observability-layer acceptance tests.
//!
//! * A live `/metrics` scrape during a loopback run must agree
//!   **exactly** with the record stream: `smx_bytes_up_total` equals the
//!   `bytes_up` column of the record the scrape observed, and the rounds
//!   counter is monotone across scrapes. Both are cut from the same
//!   cumulative totals, so equality is exact, not approximate.
//! * `smx runs diff` golden: two runs of the same config + seed are
//!   `identical` on the deterministic columns even though their wall
//!   times differ; a different seed diverges.
//! * `--watch` is non-perturbing: attaching a [`WatchObserver`] leaves
//!   the trajectory bitwise unchanged.

use smx::coordinator::{
    DistTransport, Driver, EngineFactory, ObserverControl, RoundObserver, RoundRecord, RunConfig,
    RunResult, Session,
};
use smx::data::synth;
use smx::methods::MethodSpec;
use smx::obs::http::http_get;
use smx::obs::runs::{diff_runs, summarize, DiffOutcome};
use smx::obs::{HttpEndpoint, MetricsObserver, Registry, WatchObserver};
use smx::objective::Smoothness;
use smx::runtime::native::NativeEngine;
use smx::runtime::GradEngine;
use smx::sampling::SamplingKind;
use smx::wire::runlog::RunLog;
use std::cell::RefCell;
use std::io::{self, Write};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|v| v.to_bits()).collect()
}

struct Cell {
    sm: Smoothness,
    x_star: Vec<f64>,
    mu: f64,
    factory: EngineFactory,
}

impl Cell {
    fn new(n_shards: usize) -> Cell {
        let mu = 1e-3;
        let ds = synth::generate(&synth::tiny_spec(), 11);
        let (_, shards) = ds.prepare(n_shards, 11);
        let sm = Smoothness::build(&shards, mu);
        let x_star = vec![0.0; sm.dim];
        let factory: EngineFactory = Arc::new(move |i| {
            Box::new(NativeEngine::from_shard(&shards[i], mu)) as Box<dyn GradEngine>
        });
        Cell {
            sm,
            x_star,
            mu,
            factory,
        }
    }

    fn spec(&self) -> MethodSpec {
        MethodSpec::new(
            "diana+",
            2.0,
            SamplingKind::Uniform,
            self.mu,
            vec![0.0; self.sm.dim],
        )
    }

    fn session(&self, cfg: &RunConfig) -> Session<'_> {
        Session::new(self.spec())
            .smoothness(&self.sm)
            .x_star(&self.x_star)
            .driver(Driver::Distributed {
                transport: DistTransport::Loopback { procs: 2 },
            })
            .run_config(cfg.clone())
            .engine_factory(self.factory.clone())
    }
}

fn cfg_with_seed(seed: u64) -> RunConfig {
    RunConfig {
        max_rounds: 20,
        record_every: 5,
        seed,
        ..Default::default()
    }
}

fn metric_u64(body: &str, name: &str) -> Option<u64> {
    body.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.strip_prefix(' ')))
        .and_then(|v| v.trim().parse().ok())
}

/// Observer that scrapes the live endpoint on every recorded round and
/// keeps `(record, scraped bytes_up, scraped rounds_total)` samples.
struct Scraper<'a> {
    addr: SocketAddr,
    samples: &'a RefCell<Vec<(RoundRecord, u64, u64)>>,
}

impl RoundObserver for Scraper<'_> {
    fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
        let (head, body) = http_get(self.addr, "/metrics").expect("scrape");
        assert!(head.starts_with("HTTP/1.1 200"), "scrape head: {head}");
        let bytes_up = metric_u64(&body, "smx_bytes_up_total").expect("bytes_up series");
        let rounds = metric_u64(&body, "smx_rounds_total").expect("rounds series");
        self.samples.borrow_mut().push((rec.clone(), bytes_up, rounds));
        ObserverControl::Continue
    }
}

#[test]
fn live_scrapes_agree_exactly_with_the_record_stream() {
    let cell = Cell::new(4);
    let registry = Arc::new(Registry::new(4));
    let server = HttpEndpoint::spawn("127.0.0.1:0", registry.clone()).expect("spawn endpoint");
    let addr = server.addr();

    let (head, body) = http_get(addr, "/healthz").expect("healthz");
    assert!(head.starts_with("HTTP/1.1 200"));
    assert_eq!(body, "ok\n");

    let samples = RefCell::new(Vec::new());
    let cfg = cfg_with_seed(11);
    // order matters: the MetricsObserver publishes the record into the
    // registry, then the scraper reads it back over real HTTP
    let result = cell
        .session(&cfg)
        .observer(MetricsObserver::new(registry.clone()))
        .observer(Scraper {
            addr,
            samples: &samples,
        })
        .run()
        .expect("observed run");

    let samples = samples.into_inner();
    assert_eq!(
        samples.len(),
        result.records.len(),
        "one scrape per recorded round"
    );
    let mut prev_rounds = 0u64;
    for (rec, scraped_bytes_up, scraped_rounds) in &samples {
        // exact equality: the round block mirrors the record that was
        // cut from the same cumulative totals — not a near-miss check
        assert_eq!(
            *scraped_bytes_up, rec.bytes_up,
            "round {}: /metrics bytes_up diverged from the record stream",
            rec.round
        );
        assert!(
            *scraped_rounds >= prev_rounds,
            "rounds counter went backwards ({prev_rounds} -> {scraped_rounds})"
        );
        prev_rounds = *scraped_rounds;
    }
    let (last, _, last_rounds) = samples.last().expect("non-empty");
    assert_eq!(last.round, result.records.last().unwrap().round);
    assert_eq!(
        *last_rounds as usize, last.round,
        "rounds counter tracks the recorded round"
    );

    // one more scrape after the run: the final state stays readable
    let (_, body) = http_get(addr, "/metrics").expect("final scrape");
    assert_eq!(
        metric_u64(&body, "smx_bytes_up_total"),
        Some(result.records.last().unwrap().bytes_up)
    );
    assert_eq!(
        metric_u64(&body, "smx_scrapes_total"),
        Some(samples.len() as u64 + 1),
        "every /metrics hit counted"
    );
    server.stop();
}

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("smx_obs_endpoint_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn record_run(dir: &Path, seed: u64, result: &RunResult) {
    let mut log = RunLog::create(dir, 0xD1FF, seed, "").expect("create run log");
    for rec in &result.records {
        log.record(rec);
    }
    log.finish().expect("finish run log");
}

#[test]
fn runs_diff_is_golden_on_equal_seeds_and_splits_on_different_ones() {
    let cell = Cell::new(4);
    let (a, b, c) = (tmp_dir("seed11_a"), tmp_dir("seed11_b"), tmp_dir("seed12"));
    // two independent runs, same seed: wall/phase timings differ for
    // sure, the deterministic columns must not
    record_run(&a, 11, &cell.session(&cfg_with_seed(11)).run().unwrap());
    record_run(&b, 11, &cell.session(&cfg_with_seed(11)).run().unwrap());
    record_run(&c, 12, &cell.session(&cfg_with_seed(12)).run().unwrap());

    match diff_runs(&a, &b).expect("diff a b") {
        DiffOutcome::Identical { records } => assert!(records > 0, "trivial golden run"),
        other => panic!("equal-seed runs must diff as identical, got {other:?}"),
    }
    match diff_runs(&a, &c).expect("diff a c") {
        DiffOutcome::Diverged { round, .. } => {
            assert!(round > 0, "round 0 is seed-independent (residual 1.0)")
        }
        other => panic!("different-seed runs must diverge, got {other:?}"),
    }

    // the artifact store sees what the run log wrote
    let s = summarize(&a).expect("summarize");
    assert!(s.finished);
    assert_eq!(s.seed, 11);
    assert_eq!(s.records, 5, "20 rounds at record_every=5, plus round 0");
}

/// `Write` into a shared buffer (the observer owns its sink; the test
/// keeps the other handle).
struct SharedBuf(Arc<Mutex<Vec<u8>>>);
impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[test]
fn watch_observer_is_bitwise_non_perturbing() {
    let cell = Cell::new(4);
    let cfg = cfg_with_seed(11);
    let plain = cell.session(&cfg).run().expect("plain run");

    let sink = Arc::new(Mutex::new(Vec::new()));
    let registry = Arc::new(Registry::new(4));
    let watched = cell
        .session(&cfg)
        .observer(
            WatchObserver::to_sink(Box::new(SharedBuf(sink.clone()))).registry(registry),
        )
        .run()
        .expect("watched run");

    assert_eq!(
        bits(&plain.final_x),
        bits(&watched.final_x),
        "--watch perturbed the trajectory"
    );
    assert_eq!(plain.records.len(), watched.records.len());
    for (p, w) in plain.records.iter().zip(&watched.records) {
        assert_eq!(p.round, w.round);
        assert_eq!(p.residual.to_bits(), w.residual.to_bits());
        assert_eq!(p.bytes_up, w.bytes_up);
        assert_eq!(p.coords_up, w.coords_up);
    }
    let drawn = sink.lock().unwrap();
    let text = String::from_utf8_lossy(&drawn);
    assert!(
        text.contains("smx watch"),
        "dashboard never drew: {text:?}"
    );
}
