//! Cross-driver identity as one table-driven matrix test.
//!
//! The three coordinator drivers — [`run_sim`] (sequential in-process),
//! [`run_threaded`] (one OS thread per worker over fixed-capacity SPSC
//! ring buffers, with an additional core-pinned column), and
//! [`run_distributed`](smx::wire::run_distributed) (loopback transports
//! through the wire codec, lossless `f64` payload) — must produce
//! **bitwise identical** iterates and identical communication accounting
//! over the full grid
//!
//!   {dcgd+, diana+, adiana+} × {uniform, importance-diana} × {2, 4 shards}
//!
//! with the distributed driver additionally run at both one-process-per-
//! shard and 2 shards-multiplexed-per-process. This supersedes the former
//! ad-hoc pairwise asserts (`coordinator::tests::sim_and_threaded_agree_
//! bitwise`, the per-method loop in `wire_distributed.rs`); diana++'s
//! sparse downlink and the measured-bytes accounting keep their dedicated
//! coverage in `wire_distributed.rs`.

use smx::coordinator::{run_sim, run_threaded, EngineFactory, RunConfig};
use smx::data::synth;
use smx::methods::{build, MethodSpec};
use smx::objective::Smoothness;
use smx::runtime::native::NativeEngine;
use smx::runtime::GradEngine;
use smx::sampling::SamplingKind;
use smx::wire::run_distributed_loopback;
use std::sync::Arc;

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn drivers_bitwise_identical_over_method_sampling_shard_grid() {
    let mu = 1e-3;
    for n_shards in [2usize, 4] {
        let ds = synth::generate(&synth::tiny_spec(), 11);
        let (_, shards) = ds.prepare(n_shards, 11);
        let sm = Smoothness::build(&shards, mu);
        let dim = sm.dim;
        // identity is a trajectory property; the reference point only
        // feeds the residual metric, so 0 serves
        let x_star = vec![0.0; dim];
        let cfg = RunConfig {
            max_rounds: 25,
            ..Default::default()
        };
        let shards_f = shards.clone();
        let factory: EngineFactory = Arc::new(move |i| {
            Box::new(NativeEngine::from_shard(&shards_f[i], mu)) as Box<dyn GradEngine>
        });

        for method in ["dcgd+", "diana+", "adiana+"] {
            for sampling in [SamplingKind::Uniform, SamplingKind::ImportanceDiana] {
                let cell = format!("{method}/{}/n={n_shards}", sampling.name());
                let spec = MethodSpec::new(method, 2.0, sampling, mu, vec![0.0; dim]);

                let mut m_sim = build(&spec, &sm).unwrap();
                let mut engines: Vec<Box<dyn GradEngine>> = shards
                    .iter()
                    .map(|s| Box::new(NativeEngine::from_shard(s, mu)) as Box<dyn GradEngine>)
                    .collect();
                let r_sim = run_sim(&mut m_sim, &mut engines, &x_star, &cfg);
                let sim_last = r_sim.records.last().unwrap().clone();

                // run_threaded (SPSC ring-buffer channels)
                let m_thr = build(&spec, &sm).unwrap();
                let r_thr = run_threaded(m_thr, factory.clone(), &x_star, &cfg);
                assert_eq!(
                    bits(&r_sim.final_x),
                    bits(&r_thr.final_x),
                    "{cell}: run_threaded diverged from run_sim"
                );
                let thr_last = r_thr.records.last().unwrap();
                assert_eq!(sim_last.coords_up, thr_last.coords_up, "{cell}: coords_up (threaded)");
                assert_eq!(sim_last.bits_up, thr_last.bits_up, "{cell}: bits_up (threaded)");
                assert_eq!(sim_last.bytes_up, thr_last.bytes_up, "{cell}: bytes_up (threaded)");

                // pinned column: core pinning is a scheduling hint only —
                // the synchronous ring protocol makes the trajectory
                // independent of where worker threads land
                if method == "diana+" {
                    let m_pin = build(&spec, &sm).unwrap();
                    let cfg_pin = RunConfig {
                        pin: true,
                        ..cfg.clone()
                    };
                    let r_pin = run_threaded(m_pin, factory.clone(), &x_star, &cfg_pin);
                    assert_eq!(
                        bits(&r_sim.final_x),
                        bits(&r_pin.final_x),
                        "{cell}: pinned run_threaded diverged from run_sim"
                    );
                }

                // run_distributed over loopback, f64 payload: one process
                // per shard, then 2 shards multiplexed per process
                let mut procs_grid = vec![n_shards];
                if n_shards > 2 {
                    procs_grid.push(2);
                }
                for procs in procs_grid {
                    let m_dist = build(&spec, &sm).unwrap();
                    let r_dist =
                        run_distributed_loopback(m_dist, factory.clone(), &x_star, &cfg, procs)
                            .unwrap();
                    assert_eq!(
                        bits(&r_sim.final_x),
                        bits(&r_dist.final_x),
                        "{cell}: run_distributed(procs={procs}) diverged from run_sim"
                    );
                    let dist_last = r_dist.records.last().unwrap();
                    assert_eq!(
                        sim_last.coords_up, dist_last.coords_up,
                        "{cell}: coords_up (distributed, procs={procs})"
                    );
                    assert_eq!(
                        sim_last.bits_up, dist_last.bits_up,
                        "{cell}: bits_up (distributed, procs={procs})"
                    );
                    // measured frame bytes: the sim's uplink_frame_len
                    // accounting must equal what the distributed driver
                    // actually framed — adiana+'s cells keep the delta2
                    // (two-sparse-uplinks) frame path covered here
                    assert_eq!(
                        sim_last.bytes_up, dist_last.bytes_up,
                        "{cell}: measured bytes_up (distributed, procs={procs})"
                    );
                    if procs == n_shards {
                        // one process per shard matches the sim's
                        // per-worker downlink broadcast model exactly
                        assert_eq!(
                            sim_last.bytes_down, dist_last.bytes_down,
                            "{cell}: measured bytes_down (distributed, procs={procs})"
                        );
                    }
                }
            }
        }
    }
}
