//! Cross-driver identity as one table-driven matrix test — every column
//! constructed through the [`Session`] builder, the crate's one front
//! door.
//!
//! The three coordinator drivers — [`Driver::Sim`] (sequential
//! in-process), [`Driver::Threaded`] (one OS thread per worker over
//! fixed-capacity SPSC ring buffers, with an additional core-pinned
//! column), and [`Driver::Distributed`] over loopback transports through
//! the wire codec (lossless `f64` payload) — must produce **bitwise
//! identical** iterates and identical communication accounting over the
//! full grid
//!
//!   {dcgd+, diana+, adiana+} × {uniform, importance-diana} × {2, 4 shards}
//!
//! with the distributed driver additionally run at both one-process-per-
//! shard and 2 shards-multiplexed-per-process. A second test asserts the
//! observer seam is non-perturbing: a JSONL-streaming observer attached
//! to the run leaves the trajectory bitwise unchanged versus the plain
//! collecting run, and streams exactly the collected records. diana++'s
//! sparse downlink and the measured-bytes accounting keep their dedicated
//! coverage in `wire_distributed.rs`.

use smx::coordinator::{
    DistTransport, Driver, EngineFactory, ObserverControl, RoundObserver, RoundRecord, RunConfig,
    RunResult, Session,
};
use smx::data::synth;
use smx::methods::MethodSpec;
use smx::objective::Smoothness;
use smx::runtime::native::NativeEngine;
use smx::runtime::GradEngine;
use smx::sampling::SamplingKind;
use std::sync::Arc;

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|v| v.to_bits()).collect()
}

struct Cell {
    sm: Smoothness,
    shards: Vec<smx::data::Shard>,
    x_star: Vec<f64>,
    mu: f64,
    cfg: RunConfig,
    factory: EngineFactory,
}

impl Cell {
    fn new(n_shards: usize) -> Cell {
        let mu = 1e-3;
        let ds = synth::generate(&synth::tiny_spec(), 11);
        let (_, shards) = ds.prepare(n_shards, 11);
        let sm = Smoothness::build(&shards, mu);
        // identity is a trajectory property; the reference point only
        // feeds the residual metric, so 0 serves
        let x_star = vec![0.0; sm.dim];
        let cfg = RunConfig {
            max_rounds: 25,
            ..Default::default()
        };
        let shards_f = shards.clone();
        let factory: EngineFactory = Arc::new(move |i| {
            Box::new(NativeEngine::from_shard(&shards_f[i], mu)) as Box<dyn GradEngine>
        });
        Cell {
            sm,
            shards,
            x_star,
            mu,
            cfg,
            factory,
        }
    }

    fn engines(&self) -> Vec<Box<dyn GradEngine>> {
        self.shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, self.mu)) as Box<dyn GradEngine>)
            .collect()
    }

    /// One builder, any driver: the matrix columns differ only in the
    /// `.driver(..)` argument.
    fn run(&self, spec: &MethodSpec, driver: Driver, cfg: &RunConfig) -> RunResult {
        let mut session = Session::new(spec.clone())
            .smoothness(&self.sm)
            .x_star(&self.x_star)
            .driver(driver.clone())
            .run_config(cfg.clone());
        session = match driver {
            Driver::Sim => session.engines(self.engines()),
            _ => session.engine_factory(self.factory.clone()),
        };
        session.run().expect("session run")
    }
}

#[test]
fn drivers_bitwise_identical_over_method_sampling_shard_grid() {
    for n_shards in [2usize, 4] {
        let cell0 = Cell::new(n_shards);
        for method in ["dcgd+", "diana+", "adiana+"] {
            for sampling in [SamplingKind::Uniform, SamplingKind::ImportanceDiana] {
                let cellname = format!("{method}/{}/n={n_shards}", sampling.name());
                let spec =
                    MethodSpec::new(method, 2.0, sampling, cell0.mu, vec![0.0; cell0.sm.dim]);

                let r_sim = cell0.run(&spec, Driver::Sim, &cell0.cfg);
                let sim_last = r_sim.records.last().unwrap().clone();

                // threaded driver (SPSC ring-buffer channels)
                let r_thr = cell0.run(&spec, Driver::Threaded, &cell0.cfg);
                assert_eq!(
                    bits(&r_sim.final_x),
                    bits(&r_thr.final_x),
                    "{cellname}: threaded diverged from sim"
                );
                let thr_last = r_thr.records.last().unwrap();
                assert_eq!(sim_last.coords_up, thr_last.coords_up, "{cellname}: coords_up (threaded)");
                assert_eq!(sim_last.bits_up, thr_last.bits_up, "{cellname}: bits_up (threaded)");
                assert_eq!(sim_last.bytes_up, thr_last.bytes_up, "{cellname}: bytes_up (threaded)");

                // pinned column: core pinning is a scheduling hint only —
                // the synchronous ring protocol makes the trajectory
                // independent of where worker threads land
                if method == "diana+" {
                    let cfg_pin = RunConfig {
                        pin: true,
                        ..cell0.cfg.clone()
                    };
                    let r_pin = cell0.run(&spec, Driver::Threaded, &cfg_pin);
                    assert_eq!(
                        bits(&r_sim.final_x),
                        bits(&r_pin.final_x),
                        "{cellname}: pinned threaded diverged from sim"
                    );
                }

                // distributed over loopback, f64 payload: one process per
                // shard, then 2 shards multiplexed per process
                let mut procs_grid = vec![n_shards];
                if n_shards > 2 {
                    procs_grid.push(2);
                }
                for procs in procs_grid {
                    let r_dist = cell0.run(
                        &spec,
                        Driver::Distributed {
                            transport: DistTransport::Loopback { procs },
                        },
                        &cell0.cfg,
                    );
                    assert_eq!(
                        bits(&r_sim.final_x),
                        bits(&r_dist.final_x),
                        "{cellname}: distributed(procs={procs}) diverged from sim"
                    );
                    let dist_last = r_dist.records.last().unwrap();
                    assert_eq!(
                        sim_last.coords_up, dist_last.coords_up,
                        "{cellname}: coords_up (distributed, procs={procs})"
                    );
                    assert_eq!(
                        sim_last.bits_up, dist_last.bits_up,
                        "{cellname}: bits_up (distributed, procs={procs})"
                    );
                    // measured frame bytes: the sim's uplink_frame_len
                    // accounting must equal what the distributed driver
                    // actually framed — adiana+'s cells keep the delta2
                    // (two-sparse-uplinks) frame path covered here
                    assert_eq!(
                        sim_last.bytes_up, dist_last.bytes_up,
                        "{cellname}: measured bytes_up (distributed, procs={procs})"
                    );
                    if procs == n_shards {
                        // one process per shard matches the sim's
                        // per-worker downlink broadcast model exactly
                        assert_eq!(
                            sim_last.bytes_down, dist_last.bytes_down,
                            "{cellname}: measured bytes_down (distributed, procs={procs})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn sa_quant_drivers_bitwise_identical() {
    // The smoothness-aware quantizer draws one uniform per coordinate
    // unconditionally, so its RNG consumption is value-independent and
    // the sim ≡ threaded ≡ distributed(f64) identity must hold exactly —
    // on both weightings (diag hits the Diag decompressor, root the
    // PSD-root path) and on the exact-passthrough levels=0 sentinel.
    use smx::compress::{CompressorKind, QuantWeighting};

    let cell = Cell::new(4);
    for method in ["dcgd", "diana"] {
        for (levels, weighting) in [
            (4u32, QuantWeighting::Diag),
            (4u32, QuantWeighting::Root),
            (0u32, QuantWeighting::Diag),
        ] {
            let cellname = format!("{method}/sa-quant/{}/s={levels}", weighting.name());
            let mut spec =
                MethodSpec::new(method, 1.0, SamplingKind::Uniform, cell.mu, vec![0.0; cell.sm.dim]);
            spec.compressor = CompressorKind::SaQuant;
            spec.sa_levels = levels;
            spec.sa_weighting = weighting;

            let r_sim = cell.run(&spec, Driver::Sim, &cell.cfg);
            let sim_last = r_sim.records.last().unwrap().clone();

            let r_thr = cell.run(&spec, Driver::Threaded, &cell.cfg);
            assert_eq!(
                bits(&r_sim.final_x),
                bits(&r_thr.final_x),
                "{cellname}: threaded diverged from sim"
            );
            let thr_last = r_thr.records.last().unwrap();
            assert_eq!(sim_last.coords_up, thr_last.coords_up, "{cellname}: coords_up (threaded)");
            assert_eq!(sim_last.bits_up, thr_last.bits_up, "{cellname}: bits_up (threaded)");
            assert_eq!(sim_last.bytes_up, thr_last.bytes_up, "{cellname}: bytes_up (threaded)");

            for procs in [4usize, 2] {
                let r_dist = cell.run(
                    &spec,
                    Driver::Distributed {
                        transport: DistTransport::Loopback { procs },
                    },
                    &cell.cfg,
                );
                assert_eq!(
                    bits(&r_sim.final_x),
                    bits(&r_dist.final_x),
                    "{cellname}: distributed(procs={procs}) diverged from sim"
                );
                let dist_last = r_dist.records.last().unwrap();
                assert_eq!(
                    sim_last.coords_up, dist_last.coords_up,
                    "{cellname}: coords_up (distributed, procs={procs})"
                );
                assert_eq!(
                    sim_last.bits_up, dist_last.bits_up,
                    "{cellname}: bits_up (distributed, procs={procs})"
                );
                assert_eq!(
                    sim_last.bytes_up, dist_last.bytes_up,
                    "{cellname}: measured bytes_up (distributed, procs={procs})"
                );
            }

            // quantization must actually perturb the trajectory relative
            // to the exact method (else the compressor isn't wired in) —
            // except under the levels=0 exact-passthrough sentinel
            let exact_spec =
                MethodSpec::new(method, 1.0, SamplingKind::Uniform, cell.mu, vec![0.0; cell.sm.dim]);
            let r_exact = cell.run(&exact_spec, Driver::Sim, &cell.cfg);
            if levels > 0 {
                assert_ne!(
                    bits(&r_sim.final_x),
                    bits(&r_exact.final_x),
                    "{cellname}: sa-quant trajectory identical to uncompressed — compressor not applied"
                );
            }
        }
    }
}

#[test]
fn partial_participation_drivers_bitwise_identical() {
    // The participation column: with `participation: Some(τ)` each round
    // samples an unbiased cohort of exactly τ shards (a pure function of
    // (seed, n, τ, round) — see `coordinator::membership::cohort_mask`),
    // clears the sampled-out uplink slots, and reweights cohort uplinks
    // by n/τ after accounting. All of that is driver-independent state,
    // so sim ≡ threaded ≡ distributed(f64 loopback) must stay **bitwise
    // identical** under τ < n, exactly like the full-participation grid.
    let cell = Cell::new(4);
    for method in ["dcgd+", "diana+", "adiana+"] {
        let cellname = format!("{method}/tau=2/n=4");
        let spec = MethodSpec::new(
            method,
            2.0,
            SamplingKind::ImportanceDiana,
            cell.mu,
            vec![0.0; cell.sm.dim],
        );
        let cfg_tau = RunConfig {
            participation: Some(2),
            ..cell.cfg.clone()
        };

        let r_sim = cell.run(&spec, Driver::Sim, &cfg_tau);
        let sim_last = r_sim.records.last().unwrap().clone();

        let r_thr = cell.run(&spec, Driver::Threaded, &cfg_tau);
        assert_eq!(
            bits(&r_sim.final_x),
            bits(&r_thr.final_x),
            "{cellname}: threaded diverged from sim"
        );
        let thr_last = r_thr.records.last().unwrap();
        assert_eq!(sim_last.coords_up, thr_last.coords_up, "{cellname}: coords_up (threaded)");
        assert_eq!(sim_last.bits_up, thr_last.bits_up, "{cellname}: bits_up (threaded)");

        for procs in [4usize, 2] {
            let r_dist = cell.run(
                &spec,
                Driver::Distributed {
                    transport: DistTransport::Loopback { procs },
                },
                &cfg_tau,
            );
            assert_eq!(
                bits(&r_sim.final_x),
                bits(&r_dist.final_x),
                "{cellname}: distributed(procs={procs}) diverged from sim"
            );
            let dist_last = r_dist.records.last().unwrap();
            assert_eq!(
                sim_last.coords_up, dist_last.coords_up,
                "{cellname}: coords_up (distributed, procs={procs})"
            );
            assert_eq!(
                sim_last.bits_up, dist_last.bits_up,
                "{cellname}: bits_up (distributed, procs={procs})"
            );
        }

        // sampling must actually bite: τ < n perturbs the trajectory
        // relative to full participation (else the cohort gate is dead
        // code and this test proves nothing)
        let r_full = cell.run(&spec, Driver::Sim, &cell.cfg);
        assert_ne!(
            bits(&r_sim.final_x),
            bits(&r_full.final_x),
            "{cellname}: τ<n trajectory identical to full participation — sampling not wired in"
        );
    }
}

#[test]
fn tau_equals_n_is_bitwise_todays_trajectory() {
    // τ = n clamps to full participation as a *strict no-op*: no RNG
    // stream is consumed, no uplink is scaled, no epoch frame is framed —
    // `participation: Some(n)` must be indistinguishable from
    // `participation: None` down to the last bit, on every driver.
    let cell = Cell::new(4);
    let spec = MethodSpec::new(
        "diana+",
        2.0,
        SamplingKind::ImportanceDiana,
        cell.mu,
        vec![0.0; cell.sm.dim],
    );
    let cfg_n = RunConfig {
        participation: Some(4),
        ..cell.cfg.clone()
    };
    let drivers = [
        Driver::Sim,
        Driver::Threaded,
        Driver::Distributed {
            transport: DistTransport::Loopback { procs: 2 },
        },
    ];
    for driver in drivers {
        let plain = cell.run(&spec, driver.clone(), &cell.cfg);
        let tau_n = cell.run(&spec, driver.clone(), &cfg_n);
        assert_eq!(
            bits(&plain.final_x),
            bits(&tau_n.final_x),
            "τ=n diverged from participation-off ({driver:?})"
        );
        assert_eq!(plain.records.len(), tau_n.records.len());
        let (p, t) = (plain.records.last().unwrap(), tau_n.records.last().unwrap());
        assert_eq!(p.coords_up, t.coords_up, "coords_up ({driver:?})");
        assert_eq!(p.bits_up, t.bits_up, "bits_up ({driver:?})");
        assert_eq!(p.bytes_up, t.bytes_up, "bytes_up ({driver:?})");
        assert_eq!(p.bytes_down, t.bytes_down, "bytes_down ({driver:?})");
        assert_eq!(p.coords_down, t.coords_down, "coords_down ({driver:?})");
    }
}

#[test]
fn streaming_observers_do_not_perturb_the_trajectory() {
    // Observers receive shared references after the server applies each
    // round; attaching a JSONL streaming sink (plus a counting observer)
    // must leave the trajectory bitwise unchanged versus the plain
    // collecting run, on every driver.
    struct Counter<'c> {
        seen: &'c std::cell::Cell<usize>,
    }
    impl RoundObserver for Counter<'_> {
        fn on_round(&mut self, _rec: &RoundRecord) -> ObserverControl {
            self.seen.set(self.seen.get() + 1);
            ObserverControl::Continue
        }
    }

    let cell = Cell::new(4);
    let spec = MethodSpec::new(
        "diana+",
        2.0,
        SamplingKind::ImportanceDiana,
        cell.mu,
        vec![0.0; cell.sm.dim],
    );
    let drivers = [
        Driver::Sim,
        Driver::Threaded,
        Driver::Distributed {
            transport: DistTransport::Loopback { procs: 2 },
        },
    ];
    for driver in drivers {
        let plain = cell.run(&spec, driver.clone(), &cell.cfg);

        let jsonl_path = std::env::temp_dir().join(format!(
            "smx_driver_matrix_{}.jsonl",
            match &driver {
                Driver::Sim => "sim",
                Driver::Threaded => "threaded",
                Driver::Distributed { .. } => "dist",
            }
        ));
        let seen = std::cell::Cell::new(0usize);
        let mut session = Session::new(spec.clone())
            .smoothness(&cell.sm)
            .x_star(&cell.x_star)
            .driver(driver.clone())
            .run_config(cell.cfg.clone())
            .observer(smx::coordinator::JsonlObserver::create(&jsonl_path).unwrap())
            .observer(Counter { seen: &seen });
        session = match driver {
            Driver::Sim => session.engines(cell.engines()),
            _ => session.engine_factory(cell.factory.clone()),
        };
        let observed = session.run().expect("observed session run");
        assert_eq!(seen.get(), observed.records.len(), "counter observer call count");

        assert_eq!(
            bits(&plain.final_x),
            bits(&observed.final_x),
            "observers perturbed the trajectory"
        );
        assert_eq!(plain.records.len(), observed.records.len());
        assert_eq!(
            plain.records.last().unwrap().coords_up,
            observed.records.last().unwrap().coords_up
        );

        // the stream carries exactly the records the collector kept
        let text = std::fs::read_to_string(&jsonl_path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), observed.records.len(), "jsonl line count");
        for (line, rec) in lines.iter().zip(&observed.records) {
            let j = smx::util::json::Json::parse(line).expect("valid json line");
            assert_eq!(j.get("round").as_usize().unwrap(), rec.round);
            assert_eq!(
                j.get("coords_up").as_f64().unwrap() as u64,
                rec.coords_up
            );
        }
        std::fs::remove_file(&jsonl_path).ok();
    }
}
