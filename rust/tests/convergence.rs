//! Convergence integration tests: every method reaches the theoretical
//! behaviour the paper proves for it, on a tiny problem where exact x* is
//! computed to f64 precision.
//!
//! * linearly-convergent methods (DGD, DIANA(+), ADIANA(+), ISEGA+,
//!   DIANA++) must reach a small residual;
//! * DCGD(+) converge only to the Theorem-2 neighborhood (nonzero
//!   ∇f_i(x*)), which must shrink with γ — verified via the radius bound;
//! * "+" variants must never be slower than their baselines (paper §6.2:
//!   "the new methods always outperform the baselines").

use smx::config::ExperimentConfig;
use smx::experiments::runner::{self, Prepared};
use smx::sampling::SamplingKind;

fn cfg(max_rounds: usize, target: f64) -> ExperimentConfig {
    ExperimentConfig {
        dataset: "tiny".into(),
        workers: 4,
        max_rounds,
        target_residual: target,
        record_every: 20,
        seed: 77,
        ..Default::default()
    }
}

fn prep_for(c: &ExperimentConfig, need_global: bool) -> Prepared {
    runner::prepare_with(c, need_global).unwrap()
}

#[test]
fn variance_reduced_methods_converge_linearly() {
    let c = cfg(40_000, 1e-10);
    let prep = prep_for(&c, true);
    for (method, sampling) in [
        ("dgd", SamplingKind::Uniform),
        ("diana", SamplingKind::Uniform),
        ("diana+", SamplingKind::ImportanceDiana),
        ("diana+", SamplingKind::Uniform),
        ("isega+", SamplingKind::ImportanceDiana),
        ("adiana", SamplingKind::Uniform),
        ("adiana+", SamplingKind::ImportanceAdiana),
    ] {
        let r = runner::run_one(&prep, &c, method, sampling, 2.0).unwrap();
        assert!(
            r.reached_target,
            "{method} ({sampling:?}) stalled at {:.3e} after {} rounds",
            r.final_residual(),
            r.rounds_run
        );
    }
}

#[test]
fn diana_pp_converges_linearly_at_its_own_rate() {
    // Theorem 23's γ is very conservative (the A + CM constant), so
    // DIANA++ is slow in rounds; what must hold is a clean *linear* rate:
    // equal-length round windows shrink the residual by a stable factor.
    let c = cfg(60_000, 0.0);
    let prep = prep_for(&c, true);
    let r = runner::run_one(&prep, &c, "diana++", SamplingKind::ImportanceDiana, 2.0).unwrap();
    let res_at = |round: usize| {
        r.records
            .iter()
            .filter(|rec| rec.round <= round)
            .next_back()
            .unwrap()
            .residual
    };
    let (r1, r2, r3) = (res_at(20_000), res_at(40_000), res_at(60_000));
    assert!(r3 < 1e-2, "no substantial progress: {r3:.3e}");
    let rho_a = r2 / r1;
    let rho_b = r3 / r2;
    assert!(
        rho_a < 0.7 && rho_b < 0.7,
        "not contracting: {r1:.2e} -> {r2:.2e} -> {r3:.2e}"
    );
    // stable geometric factor (within 3x — it's stochastic)
    assert!(
        rho_a / rho_b < 3.0 && rho_b / rho_a < 3.0,
        "rate not linear: ratios {rho_a:.3} vs {rho_b:.3}"
    );
}

#[test]
fn dcgd_converges_to_neighborhood_only() {
    let c = cfg(30_000, 0.0);
    let prep = prep_for(&c, false);
    for (method, sampling) in [
        ("dcgd", SamplingKind::Uniform),
        ("dcgd+", SamplingKind::ImportanceDcgd),
    ] {
        let r = runner::run_one(&prep, &c, method, sampling, 2.0).unwrap();
        let final_res = r.final_residual();
        // reaches a plateau well below the start but (generically) above
        // f64-exact convergence — the Theorem-2 neighborhood 2γσ*/(μn)
        assert!(final_res < 0.2, "{method} made no progress: {final_res:.3e}");
        // the plateau is *stable*: last quarter of records similar scale
        let recs = &r.records;
        let q = recs.len() * 3 / 4;
        let late_max = recs[q..].iter().map(|x| x.residual).fold(0.0, f64::max);
        let late_min = recs[q..].iter().map(|x| x.residual).fold(f64::MAX, f64::min);
        assert!(
            late_max / late_min.max(1e-300) < 1e4,
            "{method} neighborhood not stable: [{late_min:.2e}, {late_max:.2e}]"
        );
    }
}

#[test]
fn plus_methods_never_slower_than_baselines() {
    // Figure-2 setup: uniform τ=1, start near optimum
    let mut c = cfg(30_000, 1e-8);
    c.start_near_opt = true;
    let prep = prep_for(&c, false);
    for (plus, base) in [("diana+", "diana"), ("adiana+", "adiana")] {
        let rp = runner::run_one(&prep, &c, plus, SamplingKind::Uniform, 1.0).unwrap();
        let rb = runner::run_one(&prep, &c, base, SamplingKind::Uniform, 1.0).unwrap();
        let ip = rp.rounds_to(1e-6).unwrap_or(usize::MAX);
        let ib = rb.rounds_to(1e-6).unwrap_or(usize::MAX);
        assert!(
            ip as f64 <= ib as f64 * 1.10 || ip == usize::MAX && ib == usize::MAX,
            "{plus} ({ip}) slower than {base} ({ib})"
        );
    }
}

#[test]
fn importance_sampling_beats_uniform_for_diana_plus() {
    let c = cfg(60_000, 1e-9);
    let prep = prep_for(&c, false);
    let imp = runner::run_one(&prep, &c, "diana+", SamplingKind::ImportanceDiana, 1.0).unwrap();
    let uni = runner::run_one(&prep, &c, "diana+", SamplingKind::Uniform, 1.0).unwrap();
    let ii = imp.rounds_to(1e-8).expect("importance did not converge");
    let iu = uni.rounds_to(1e-8).unwrap_or(c.max_rounds);
    assert!(
        ii as f64 <= iu as f64 * 1.05,
        "importance ({ii}) should not lose to uniform ({iu})"
    );
}

#[test]
fn acceleration_helps_at_scale() {
    // ADIANA+ should beat DIANA+ in rounds on an ill-conditioned-enough
    // problem; at tiny scale we only require it converges and is not
    // dramatically worse.
    let c = cfg(60_000, 1e-9);
    let prep = prep_for(&c, false);
    let a = runner::run_one(&prep, &c, "adiana+", SamplingKind::ImportanceAdiana, 1.0).unwrap();
    assert!(a.reached_target, "adiana+ stalled at {:.3e}", a.final_residual());
}

#[test]
fn diana_pp_sparse_downlink_saves_broadcast() {
    let c = cfg(3_000, 0.0);
    let prep = prep_for(&c, true);
    let pp = runner::run_one(&prep, &c, "diana++", SamplingKind::ImportanceDiana, 2.0).unwrap();
    let dp = runner::run_one(&prep, &c, "diana+", SamplingKind::ImportanceDiana, 2.0).unwrap();
    let down_pp = pp.records.last().unwrap().coords_down;
    let down_dp = dp.records.last().unwrap().coords_down;
    assert!(
        down_pp < down_dp / 2,
        "diana++ downlink {down_pp} not sparser than diana+ {down_dp}"
    );
}

#[test]
fn deterministic_given_seed() {
    let c = cfg(200, 0.0);
    let prep = prep_for(&c, false);
    let r1 = runner::run_one(&prep, &c, "diana+", SamplingKind::ImportanceDiana, 1.0).unwrap();
    let r2 = runner::run_one(&prep, &c, "diana+", SamplingKind::ImportanceDiana, 1.0).unwrap();
    assert_eq!(r1.final_x, r2.final_x);
    assert_eq!(
        r1.records.last().unwrap().coords_up,
        r2.records.last().unwrap().coords_up
    );
}
