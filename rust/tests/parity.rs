//! Three-layer integration: the PJRT engine (AOT JAX/Pallas artifacts)
//! must agree with the native Rust oracle to f64 precision, and a full
//! distributed run must produce identical trajectories under either
//! engine.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it) and
//! a build with the `pjrt` feature; without it this file compiles empty.

#![cfg(feature = "pjrt")]

use smx::data::synth;
use smx::objective::logreg::LogReg;
use smx::runtime::artifact::Manifest;
use smx::runtime::native::NativeEngine;
use smx::runtime::pjrt::PjrtEngine;
use smx::runtime::GradEngine;
use smx::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    smx::runtime::artifact::default_dir()
}

fn tiny_shards() -> Vec<smx::data::Shard> {
    let ds = synth::generate(&synth::tiny_spec(), 21);
    let (_, shards) = ds.prepare(4, 21);
    shards
}

#[test]
fn pjrt_grad_matches_native() {
    let manifest = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
    let shards = tiny_shards();
    let mu = 1e-3;
    let mut rng = Rng::new(1);
    for shard in &shards {
        let mut pjrt = PjrtEngine::from_shard(&manifest, shard, mu).expect("pjrt engine");
        let mut native = NativeEngine::from_shard(shard, mu);
        let d = shard.dim();
        for _ in 0..5 {
            let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let mut g_pjrt = vec![0.0; d];
            let mut g_native = vec![0.0; d];
            pjrt.grad_into(&x, &mut g_pjrt);
            native.grad_into(&x, &mut g_native);
            for j in 0..d {
                assert!(
                    (g_pjrt[j] - g_native[j]).abs() < 1e-12 * (1.0 + g_native[j].abs()),
                    "grad mismatch at {j}: pjrt={} native={}",
                    g_pjrt[j],
                    g_native[j]
                );
            }
        }
    }
}

#[test]
fn pjrt_loss_matches_native() {
    let manifest = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
    let shards = tiny_shards();
    let mu = 1e-3;
    let mut rng = Rng::new(2);
    let shard = &shards[0];
    let mut pjrt = PjrtEngine::from_shard(&manifest, shard, mu).expect("pjrt engine");
    let obj = LogReg::from_shard(shard, mu);
    for _ in 0..5 {
        let x: Vec<f64> = (0..shard.dim()).map(|_| rng.normal()).collect();
        let l_pjrt = pjrt.loss(&x);
        let l_native = obj.loss(&x);
        assert!(
            (l_pjrt - l_native).abs() < 1e-12 * (1.0 + l_native.abs()),
            "loss mismatch: {l_pjrt} vs {l_native}"
        );
    }
}

#[test]
fn distributed_run_identical_under_both_engines() {
    use smx::coordinator::{RunConfig, Session};
    use smx::methods::MethodSpec;
    use smx::objective::{Problem, Smoothness};
    use smx::sampling::SamplingKind;

    let manifest = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
    let shards = tiny_shards();
    let mu = 1e-3;
    let sm = Smoothness::build(&shards, mu);
    let problem = Problem::from_shards(&shards, mu);
    let sol = smx::methods::solve::solve_opt(&problem, &sm, 1e-13, 20_000);

    let spec = MethodSpec::new(
        "diana+",
        2.0,
        SamplingKind::ImportanceDiana,
        mu,
        vec![0.0; sm.dim],
    );
    let cfg = RunConfig {
        max_rounds: 30,
        ..Default::default()
    };

    let native_engines: Vec<Box<dyn GradEngine>> = shards
        .iter()
        .map(|s| Box::new(NativeEngine::from_shard(s, mu)) as Box<dyn GradEngine>)
        .collect();
    let r_native = Session::new(spec.clone())
        .smoothness(&sm)
        .x_star(&sol.x_star)
        .engines(native_engines)
        .run_config(cfg.clone())
        .run()
        .unwrap();

    let pjrt_engines: Vec<Box<dyn GradEngine>> = shards
        .iter()
        .map(|s| {
            Box::new(PjrtEngine::from_shard(&manifest, s, mu).expect("pjrt engine"))
                as Box<dyn GradEngine>
        })
        .collect();
    let r_pjrt = Session::new(spec)
        .smoothness(&sm)
        .x_star(&sol.x_star)
        .engines(pjrt_engines)
        .run_config(cfg)
        .run()
        .unwrap();

    // identical sampling sequences + f64-exact gradients ⇒ near-identical
    // trajectories (tiny drift allowed for XLA reassociation)
    let dx = smx::linalg::vector::dist2(&r_native.final_x, &r_pjrt.final_x).sqrt();
    let scale = smx::linalg::vector::norm(&r_native.final_x).max(1e-9);
    assert!(
        dx / scale < 1e-9,
        "engines diverged: rel dist {} (native res {:.3e}, pjrt res {:.3e})",
        dx / scale,
        r_native.final_residual(),
        r_pjrt.final_residual()
    );
    assert_eq!(
        r_native.records.last().unwrap().coords_up,
        r_pjrt.records.last().unwrap().coords_up,
        "communication accounting must be identical"
    );
}

#[test]
fn pjrt_wgrad_artifact_loads_and_runs() {
    // the wgrad artifact (whitened gradient difference, protocol (7)) is
    // exercised end-to-end: L^{†1/2}(∇f − h) computed by the artifact must
    // match the native root application.
    use smx::objective::smoothness::build_local;
    use xla::{Literal, PjRtClient};

    let manifest = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
    let shards = tiny_shards();
    let shard = &shards[1];
    let (m, d) = (shard.num_points(), shard.dim());
    let mu = 1e-3;

    let entry = manifest.find("wgrad", m, d).expect("wgrad artifact");
    let client = PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(entry.file.to_str().unwrap()).unwrap();
    let exe = client
        .compile(&xla::XlaComputation::from_proto(&proto))
        .unwrap();

    let loc = build_local(&shard.a, mu);
    let r_mat = loc.root.to_dense_pow(-0.5);

    let mut rng = Rng::new(3);
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let h: Vec<f64> = (0..d).map(|_| rng.normal() * 0.1).collect();

    let x_lit = Literal::vec1(x.as_slice());
    let a_lit = Literal::vec1(shard.a.to_dense_buffer().as_slice())
        .reshape(&[m as i64, d as i64])
        .unwrap();
    let b_lit = Literal::vec1(shard.b.as_slice());
    let mu_lit = Literal::scalar(mu);
    let r_lit = Literal::vec1(r_mat.data.as_slice())
        .reshape(&[d as i64, d as i64])
        .unwrap();
    let h_lit = Literal::vec1(h.as_slice());

    let out = exe
        .execute::<Literal>(&[x_lit, a_lit, b_lit, mu_lit, r_lit, h_lit])
        .unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let got = out.to_vec::<f64>().unwrap();

    // native reference
    let obj = LogReg::from_shard(shard, mu);
    let mut g = obj.grad(&x);
    for j in 0..d {
        g[j] -= h[j];
    }
    let want = loc.root.apply_pow(-0.5, &g);
    for j in 0..d {
        assert!(
            (got[j] - want[j]).abs() < 1e-10 * (1.0 + want[j].abs()),
            "wgrad mismatch at {j}: {} vs {}",
            got[j],
            want[j]
        );
    }
}
