//! Wire-codec property tests: lossless `f64` round-trips for adversarial
//! (NaN-free) value distributions, lossy payloads within their stated
//! error bounds, empty/full-dimension messages, `*_frame_len` ==
//! actual encoded size (the `bytes_up` accounting consistency), and the
//! headline inequality: measured delta-varint bytes beat the modeled
//! `coords·(float_bits+⌈log₂d⌉)` account for Top-k uplinks.

use smx::compress::{topk_compress, SparseMsg};
use smx::methods::{Downlink, Uplink};
use smx::prop_assert;
use smx::util::prop;
use smx::util::rng::Rng;
use smx::wire::codec::{
    downlink_frame_len, get_downlink, get_uplink, peek_uplink_shard, put_downlink, put_uplink,
    uplink_frame_len, FRAME_PREFIX,
};
use smx::wire::Payload;

/// Adversarial-but-finite value generator: mixes unit-scale normals,
/// huge and tiny exponents, subnormals, exact zeros and negative zeros.
fn adversarial_value(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => rng.normal(),
        1 => rng.normal() * 1e300,
        2 => rng.normal() * 1e-300,
        3 => rng.normal() * f64::MIN_POSITIVE * 0.5, // subnormal range
        4 => 0.0,
        5 => -0.0,
        6 => rng.normal() * 1e18,
        _ => rng.uniform_in(-1.0, 1.0),
    }
}

/// Random sorted, duplicate-free index set of size k over 0..d.
fn sorted_indices(rng: &mut Rng, d: usize, k: usize) -> Vec<u32> {
    let mut idx: Vec<usize> = rng.sample_indices(d, k);
    idx.sort_unstable();
    idx.into_iter().map(|i| i as u32).collect()
}

fn random_msg(rng: &mut Rng, d: usize, k: usize, sorted: bool) -> SparseMsg {
    let mut m = SparseMsg::new();
    let mut idx = sorted_indices(rng, d, k);
    if !sorted {
        rng.shuffle(&mut idx);
    }
    for i in idx {
        m.push(i, adversarial_value(rng));
    }
    m
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_uplink_f64_roundtrip_bitwise() {
    prop::check("uplink f64 roundtrip", |rng| {
        let d = 1 + rng.below(3000);
        let k = rng.below(d.min(200) + 1);
        let sorted = rng.bernoulli(0.7);
        let delta = random_msg(rng, d, k, sorted);
        let delta2 = if rng.bernoulli(0.3) {
            let k2 = rng.below(d.min(50) + 1);
            Some(random_msg(rng, d, k2, sorted))
        } else {
            None
        };
        let up = Uplink { delta, delta2 };
        let shard = rng.below(100_000);
        let mut body = Vec::new();
        put_uplink(&mut body, &up, shard, Payload::F64).unwrap();
        prop_assert!(
            body.len() + FRAME_PREFIX == uplink_frame_len(&up, shard, Payload::F64),
            "frame_len {} != encoded {}",
            uplink_frame_len(&up, shard, Payload::F64),
            body.len() + FRAME_PREFIX
        );
        prop_assert!(
            peek_uplink_shard(&body).map_err(|e| e.to_string())? == shard,
            "peeked shard mismatch"
        );
        let mut dec = Uplink::default();
        let got = get_uplink(&body, d, &mut dec).map_err(|e| e.to_string())?;
        prop_assert!(got == shard, "shard {got} != {shard}");
        prop_assert!(dec.delta.idx == up.delta.idx, "idx order changed");
        prop_assert!(bits_eq(&dec.delta.val, &up.delta.val), "values not bitwise");
        match (&dec.delta2, &up.delta2) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                prop_assert!(a.idx == b.idx && bits_eq(&a.val, &b.val), "delta2 mismatch")
            }
            _ => return Err("delta2 presence changed".into()),
        }
        Ok(())
    });
}

#[test]
fn prop_frame_len_consistency_all_payloads() {
    prop::check("frame_len == encoded len for every payload", |rng| {
        let d = 1 + rng.below(500);
        let k = rng.below(d + 1);
        let sorted = rng.bernoulli(0.5);
        let up = Uplink {
            delta: random_msg(rng, d, k, sorted),
            delta2: None,
        };
        let shard = rng.below(300);
        for p in Payload::ALL {
            let mut body = Vec::new();
            put_uplink(&mut body, &up, shard, p).unwrap();
            prop_assert!(
                body.len() + FRAME_PREFIX == uplink_frame_len(&up, shard, p),
                "{}: frame_len {} != encoded {}",
                p.name(),
                uplink_frame_len(&up, shard, p),
                body.len() + FRAME_PREFIX
            );
            let mut dec = Uplink::default();
            get_uplink(&body, d, &mut dec).map_err(|e| e.to_string())?;
            prop_assert!(dec.delta.idx == up.delta.idx, "{}: idx changed", p.name());
        }
        Ok(())
    });
}

#[test]
fn prop_lossy_payloads_within_error_bounds() {
    prop::check("lossy payload error bounds", |rng| {
        let d = 1 + rng.below(300);
        let k = 1 + rng.below(d);
        // finite, single-scale values (the lossy contract excludes NaN/Inf)
        let mut up = Uplink::default();
        for i in sorted_indices(rng, d, k) {
            up.delta.push(i, rng.normal() * 10f64.powi(rng.below(9) as i32 - 4));
        }
        let scale = up.delta.val.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
        for p in [Payload::F32, Payload::Q16, Payload::Q8, Payload::Q4] {
            let mut body = Vec::new();
            put_uplink(&mut body, &up, 0, p).unwrap();
            let mut dec = Uplink::default();
            get_uplink(&body, d, &mut dec).map_err(|e| e.to_string())?;
            prop_assert!(dec.delta.idx == up.delta.idx, "{}: idx changed", p.name());
            for (o, g) in up.delta.val.iter().zip(&dec.delta.val) {
                if p == Payload::F32 {
                    // exact spec: the decoded value IS the f32 cast
                    prop_assert!(
                        g.to_bits() == f64::from(*o as f32).to_bits(),
                        "f32: {g} != cast of {o}"
                    );
                } else {
                    let bound = p.max_abs_err(scale) * (1.0 + 1e-12);
                    prop_assert!(
                        (o - g).abs() <= bound,
                        "{}: |{o} - {g}| > {bound} (scale {scale})",
                        p.name()
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_full_dimension_messages() {
    let mut rng = Rng::new(7);
    for d in [1usize, 2, 123, 1024] {
        for p in Payload::ALL {
            // empty
            let empty = Uplink::default();
            let mut body = Vec::new();
            put_uplink(&mut body, &empty, 0, p).unwrap();
            assert_eq!(body.len() + FRAME_PREFIX, uplink_frame_len(&empty, 0, p));
            let mut dec = Uplink::default();
            get_uplink(&body, d, &mut dec).unwrap();
            assert!(dec.delta.is_empty());

            // full dimension (every coordinate present: gap varints all 1)
            let mut full = Uplink::default();
            for j in 0..d {
                full.delta.push(j as u32, rng.uniform_in(-1.0, 1.0));
            }
            body.clear();
            put_uplink(&mut body, &full, 1, p).unwrap();
            assert_eq!(body.len() + FRAME_PREFIX, uplink_frame_len(&full, 1, p));
            let mut dec = Uplink::default();
            get_uplink(&body, d, &mut dec).unwrap();
            assert_eq!(dec.delta.coords(), d);
            assert_eq!(dec.delta.idx, full.delta.idx);
        }
    }
}

#[test]
fn dense_downlink_roundtrip_and_len_all_payloads() {
    let mut rng = Rng::new(11);
    for d in [1usize, 17, 512] {
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        for p in Payload::ALL {
            for down in [
                Downlink::Dense {
                    x: x.clone(),
                    w: None,
                },
                Downlink::Dense {
                    x: x.clone(),
                    w: Some(w.clone()),
                },
                Downlink::Init { x: x.clone() },
            ] {
                let mut body = Vec::new();
                put_downlink(&mut body, &down, p).unwrap();
                assert_eq!(
                    body.len() + FRAME_PREFIX,
                    downlink_frame_len(&down, p),
                    "{} downlink frame_len mismatch",
                    p.name()
                );
                let mut dec = Downlink::Init { x: Vec::new() };
                get_downlink(&body, d, &mut dec).unwrap();
                if p == Payload::F64 {
                    match (&down, &dec) {
                        (Downlink::Dense { x: a, w: u }, Downlink::Dense { x: b, w: v }) => {
                            assert!(bits_eq(a, b));
                            match (u, v) {
                                (None, None) => {}
                                (Some(u), Some(v)) => assert!(bits_eq(u, v)),
                                _ => panic!("w presence changed"),
                            }
                        }
                        (Downlink::Init { x: a }, Downlink::Init { x: b }) => {
                            assert!(bits_eq(a, b))
                        }
                        _ => panic!("variant changed"),
                    }
                }
            }
        }
    }
}

/// The acceptance inequality: for Top-k uplinks at large d, the measured
/// encoded bytes (f64 values + delta-varint indices, frame prefix
/// included) stay at or below the modeled `coords·(64+⌈log₂d⌉)/8` bytes.
#[test]
fn topk_measured_bytes_beat_modeled_bits() {
    let mut rng = Rng::new(0xC0DEC);
    for (d, k) in [(7129usize, 128usize), (7129, 512), (4096, 256), (2048, 128)] {
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut up = Uplink::default();
        topk_compress(&x, k, &mut up.delta);
        assert_eq!(up.delta.coords(), k);
        let measured = uplink_frame_len(&up, 0, Payload::F64) as u64;
        let modeled_bits = up.delta.bits(d, 64);
        assert!(
            measured <= modeled_bits / 8,
            "d={d} k={k}: measured {measured} B > modeled {} B",
            modeled_bits / 8
        );
        // and the f32 payload halves it again (well under the 32-bit model)
        let measured32 = uplink_frame_len(&up, 0, Payload::F32) as u64;
        assert!(measured32 <= up.delta.bits(d, 32) / 8);
        // sanity: the length helper matches a real encode
        let mut body = Vec::new();
        put_uplink(&mut body, &up, 0, Payload::F64).unwrap();
        assert_eq!(measured as usize, body.len() + FRAME_PREFIX);
    }
}
