//! Chaos matrix: scripted faults (`FaultPlan`) × methods, every cell
//! asserting **bitwise identity** to the sim driver.
//!
//! The four failure modes from the `wire::runtime` failure model, each
//! driven by a `--fault-plan` schedule instead of ad-hoc flags:
//!
//! * **Server kill + restart** — `kill-server@rN` aborts the run loop
//!   without a clean shutdown (workers see EOF, as under SIGKILL); a
//!   second `serve_on` pointed at the same `--run-dir` resumes from the
//!   last committed snapshot + journal suffix while the *same* worker
//!   threads ride out the gap on retry/backoff. `check_sim` inside the
//!   resumed serve asserts final iterates AND coords_up against the sim
//!   driver — the crash must be invisible in the trajectory.
//! * **Corrupted downlink** — `corrupt-downlink@rN` flips one seeded bit
//!   in a framed downlink. The CRC32 trailer turns that into a detected
//!   receive error; the victim worker reconnects via backoff and the
//!   journal replay retransmits the clean bytes.
//! * **Scripted worker kill** — `kill@rN:wK` makes the worker hosting
//!   shard K vanish on receipt of the round-N downlink (≡ the old
//!   `--die-after`, but shard-addressed so the schedule is deterministic
//!   even though assignment groups race between processes).
//! * **Dropped uplink** — `drop-uplink@rN:wK` computes the round but
//!   severs instead of replying; a parked standby inherits the shards
//!   and the journal replay regenerates the missing uplink.
//!
//! Delay events (`delay@rN:MSms`) ride along in the worker-kill cell to
//! show slowness is absorbed without trace. The restart cell runs for
//! diana+, diana++ (sparse downlink + pending server message), and
//! adiana+ (accelerated server state) — the three methods with the most
//! server/worker state to lose.
//!
//! * **Scripted relay kill** — `kill@rN:relay` makes an aggregation-tier
//!   relay (`wire::relay`) vanish on the round-N downlink, taking its
//!   whole subtree's connectivity with it; a replacement relay on the
//!   same address rejoins and is caught up via journal replay while the
//!   orphaned workers reconnect through their own backoff loops.
//!
//! Every run is constructed through the `serve_on` front door, exactly
//! like `smx serve`.

use smx::config::ExperimentConfig;
use smx::coordinator::membership::cohort_mask;
use smx::sampling::SamplingKind;
use smx::wire::{
    relay_on, serve_on, worker_connect, worker_connect_with, FaultPlan, RelayOpts, WorkerOpts,
    KILLED_MARKER,
};
use std::net::TcpListener;
use std::path::Path;
use std::time::Duration;

fn chaos_cfg(method: &str, sampling: SamplingKind, scenario: &str) -> ExperimentConfig {
    let slug = format!("smx_chaos_{scenario}_{}", method.replace('+', "p"));
    ExperimentConfig {
        dataset: "tiny".into(),
        methods: vec![method.into()],
        sampling,
        tau: 2.0,
        workers: 4,
        max_rounds: 40,
        target_residual: 0.0,
        record_every: 1,
        seed: 77,
        out_dir: std::env::temp_dir().join(slug),
        ..Default::default()
    }
}

/// Generous retry budget so a worker rides out a full server
/// kill-rebind-restart cycle; small base so the tests stay fast.
fn resilient() -> WorkerOpts {
    WorkerOpts {
        max_retries: 20,
        retry_base_ms: 25,
        ..Default::default()
    }
}

/// Rebind an address the previous listener just vacated. std's
/// `TcpListener` sets SO_REUSEADDR, so lingering TIME_WAIT sockets from
/// the killed server don't block this; the retry only covers the instant
/// between the old listener's drop and the kernel releasing it.
fn bind_retry(addr: &str) -> TcpListener {
    for _ in 0..200 {
        match TcpListener::bind(addr) {
            Ok(l) => return l,
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
    panic!("could not rebind {addr} for the restarted server");
}

fn fresh_dir(path: &Path) {
    std::fs::remove_dir_all(path).ok();
}

#[test]
fn server_kill_and_restart_resumes_bitwise_identical() {
    // kill-server@r11 with checkpoint cadence 4: the round-8 snapshot is
    // the last committed one, so the durable state at the kill is
    // {snapshot@8} + {journal downlinks 9..11}. The restarted serve must
    // (a) verify its regenerated downlinks against that journal suffix,
    // (b) restore both rejoining workers from the snapshot blobs, and
    // (c) finish rounds 9..40 bitwise identical to an undisturbed sim
    // run. The workers are NOT restarted — the same threads reconnect
    // through the retry/backoff loop while the port is down.
    for (method, sampling) in [
        ("diana+", SamplingKind::ImportanceDiana),
        ("diana++", SamplingKind::Uniform),
        ("adiana+", SamplingKind::Uniform),
    ] {
        let mut cfg = chaos_cfg(method, sampling, "restart");
        let run_dir = std::env::temp_dir().join(format!(
            "smx_chaos_rundir_{}",
            method.replace('+', "p")
        ));
        fresh_dir(&run_dir);
        cfg.checkpoint_every = 4;
        cfg.wire.workers = 2;
        cfg.wire.worker_timeout = 20.0;
        cfg.wire.run_dir = Some(run_dir.display().to_string());
        cfg.wire.fault_plan = Some("kill-server@r11".into());

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || worker_connect_with(&addr, resilient()))
            })
            .collect();

        let err = serve_on(listener, &cfg, false)
            .expect_err(&format!("{method}: planned kill must surface as an error"));
        let msg = format!("{err:#}");
        assert!(
            msg.contains(KILLED_MARKER) && msg.contains("round 11"),
            "{method}: expected the planned-kill marker, got: {msg}"
        );
        assert!(
            run_dir.join("base.bin").is_file(),
            "{method}: the kill left no committed run log behind"
        );

        // Restart: same trajectory identity (canonical config), no fault
        // plan this time — re-arming the kill would just loop forever.
        cfg.wire.fault_plan = None;
        let listener = bind_retry(&addr);
        serve_on(listener, &cfg, true).unwrap_or_else(|e| {
            panic!("{method}: restarted serve_on --check-sim failed: {e:#}")
        });
        for w in workers {
            w.join().unwrap().expect("worker must survive the restart via backoff");
        }
        fresh_dir(&run_dir);
        fresh_dir(&cfg.out_dir);
    }
}

#[test]
fn corrupted_downlink_is_detected_and_retransmitted() {
    // corrupt-downlink@r9 flips one seeded bit in the round-9 downlink
    // frame to the first live connection. With CRC trailers on (the
    // default) the victim's recv fails instead of silently poisoning the
    // trajectory; the worker reconnects through its backoff loop and the
    // rejoin replay streams the clean journal copy. check_sim then proves
    // the corruption is invisible: final iterates and coords_up are
    // bitwise identical to the sim driver.
    let mut cfg = chaos_cfg("diana+", SamplingKind::ImportanceDiana, "corrupt");
    cfg.wire.workers = 2;
    cfg.wire.worker_timeout = 20.0;
    cfg.wire.fault_plan = Some("corrupt-downlink@r9".into());
    assert!(cfg.wire.crc, "CRC trailers must be on by default");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || worker_connect_with(&addr, resilient()))
        })
        .collect();

    serve_on(listener, &cfg, true).expect("serve_on --check-sim under downlink corruption");
    for w in workers {
        w.join().unwrap().expect("corrupted worker must recover via reconnect");
    }
    fresh_dir(&cfg.out_dir);
}

#[test]
fn scripted_worker_kill_and_delay_with_standby_rejoin() {
    // Both workers carry the same plan; `:w0` makes exactly the process
    // hosting shard 0 vanish on the round-6 downlink, whichever thread
    // that turned out to be (assignment groups are handed out in accept
    // order, which races). The unqualified delay slows every worker's
    // round 3 by 10 ms — slowness must leave no trace. A parked standby
    // inherits the orphaned shards via journal replay.
    let mut cfg = chaos_cfg("diana+", SamplingKind::ImportanceDiana, "kill");
    cfg.wire.workers = 2;
    cfg.wire.worker_timeout = 20.0;
    let plan = FaultPlan::parse("kill@r6:w0;delay@r3:10ms", 0).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let initial: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let fault = plan.clone();
            std::thread::spawn(move || {
                worker_connect_with(
                    &addr,
                    WorkerOpts {
                        fault: Some(fault),
                        ..Default::default()
                    },
                )
            })
        })
        .collect();
    let replacement = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(400));
        worker_connect(&addr)
    });

    serve_on(listener, &cfg, true).expect("serve_on --check-sim under scripted kill + delay");
    for w in initial {
        w.join().unwrap().expect("scripted worker (clean injected exit)");
    }
    replacement.join().unwrap().expect("replacement worker");
    fresh_dir(&cfg.out_dir);
}

#[test]
fn scripted_relay_kill_recovers_through_replacement_and_replay() {
    // kill@r6:relay — the relay vanishes on receipt of the round-6
    // downlink without forwarding it, so the server loses the whole
    // shard group at once (the worst single failure the topology can
    // produce). The relay-addressed event is invisible to the workers
    // sharing the plan string: worker_event() filters `:relay` events,
    // exactly like the server ignores worker-addressed ones. A
    // replacement relay rebinds the vacated address, rejoins, and the
    // journal replay + live round erase the gap; check_sim proves it.
    let mut cfg = chaos_cfg("diana+", SamplingKind::ImportanceDiana, "relaykill");
    cfg.wire.relays = Some("2".into());
    cfg.wire.worker_timeout = 20.0;
    let plan = FaultPlan::parse("kill@r6:relay", 0).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server_addr = listener.local_addr().unwrap().to_string();

    let doomed_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let doomed_addr = doomed_listener.local_addr().unwrap().to_string();
    let doomed = {
        let up = server_addr.clone();
        let fault = plan.clone();
        std::thread::spawn(move || {
            relay_on(
                doomed_listener,
                &up,
                RelayOpts {
                    downstream: 2,
                    fault: Some(fault),
                    ..Default::default()
                },
            )
        })
    };
    let replacement = {
        let up = server_addr.clone();
        let addr = doomed_addr.clone();
        std::thread::spawn(move || {
            let listener = bind_retry(&addr);
            relay_on(
                listener,
                &up,
                RelayOpts {
                    downstream: 2,
                    ..Default::default()
                },
            )
        })
    };
    let healthy_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let healthy_addr = healthy_listener.local_addr().unwrap().to_string();
    let healthy = {
        let up = server_addr.clone();
        std::thread::spawn(move || {
            relay_on(
                healthy_listener,
                &up,
                RelayOpts {
                    downstream: 2,
                    ..Default::default()
                },
            )
        })
    };

    let workers: Vec<_> = [&doomed_addr, &healthy_addr, &doomed_addr, &healthy_addr]
        .into_iter()
        .map(|a| {
            let addr = a.clone();
            std::thread::spawn(move || worker_connect_with(&addr, resilient()))
        })
        .collect();

    serve_on(listener, &cfg, true).expect("serve_on --check-sim under a scripted relay kill");
    doomed.join().unwrap().expect("doomed relay (clean injected exit)");
    replacement.join().unwrap().expect("replacement relay");
    healthy.join().unwrap().expect("healthy relay");
    for w in workers {
        w.join().unwrap().expect("worker must survive the relay kill via backoff");
    }
    fresh_dir(&cfg.out_dir);
}

#[test]
fn paused_sampled_out_worker_survives_the_grace_window() {
    // The partial-participation grace-window regression: a worker whose
    // heartbeat path wedges (`pause@r2:w0` — sticky, it still answers
    // its downlinks) while its shard sits out several consecutive
    // cohorts sends *nothing* for the whole stretch. The server must
    // not declare it dead on re-entry: the per-round epoch broadcast
    // doubles as a liveness probe (a successful send to a fully
    // sampled-out connection refreshes its grace window), and the
    // silence check only polices shards actually being gathered. Before
    // that fix the worker was killed the instant its shard re-entered
    // the cohort, with the stale `last_seen` from its last uplink; with
    // `max_retries: 0` below, such a false death fails the join.
    //
    // The schedule is computed, not guessed: cohorts are a pure
    // function of (seed, n, τ, round) via `cohort_mask`, so the test
    // scans for a seed whose draw has STRETCH consecutive shard-0-free
    // cohorts followed by a re-entry, then plants `delay@` events on
    // exactly the cohort workers of those rounds. The silent window is
    // stretched past the timeout (STRETCH × 1000 ms vs 3 s) while any
    // single round stays well inside it (≤ 2 × 1000 ms), so the test
    // discriminates the fix from the bug with a second of margin on
    // both sides.
    const N: usize = 3;
    const TAU: usize = 1;
    const ROUNDS: usize = 24;
    const STRETCH: usize = 4;
    const DELAY_MS: u64 = 1000;

    let mut scratch = Vec::new();
    let mut mask = Vec::new();
    let mut found = None;
    'seeds: for seed in 1..2000u64 {
        // masks[i] is round i+1's cohort (rounds are 1-based)
        let masks: Vec<Vec<bool>> = (1..=ROUNDS as u64)
            .map(|r| {
                cohort_mask(seed, N, TAU, r, &mut scratch, &mut mask);
                mask.clone()
            })
            .collect();
        // a run of STRETCH consecutive rounds a..=b with shard 0
        // sampled out, a re-entry at b+1, and room for the round-2
        // pause to land first
        for b in (STRETCH + 2)..ROUNDS {
            let a = b + 1 - STRETCH;
            if (a..=b).all(|r| !masks[r - 1][0]) && masks[b][0] {
                found = Some((seed, a, b, masks));
                break 'seeds;
            }
        }
    }
    let (seed, a, b, masks) =
        found.expect("no seed < 2000 with a long enough sampled-out stretch for shard 0");

    // Delay exactly the cohort worker of each stretch round. Worker-side
    // rounds are counted in live downlinks seen, so the shard-s worker's
    // D-th downlink (D = s's cohort count through round r) lands on
    // server round r. Unqualified delays also fire on the other workers
    // at *their* D-th downlinks — harmless strays, each bounded by the
    // single-round analysis above.
    let mut delays = std::collections::BTreeSet::new();
    for r in a..=b {
        let s = masks[r - 1].iter().position(|&x| x).expect("τ=1 cohort");
        delays.insert((1..=r).filter(|&q| masks[q - 1][s]).count());
    }
    let mut plan_str = String::from("pause@r2:w0");
    for d in &delays {
        plan_str.push_str(&format!(";delay@r{d}:{DELAY_MS}ms"));
    }
    let plan = FaultPlan::parse(&plan_str, 0).unwrap();

    let mut cfg = chaos_cfg("diana+", SamplingKind::ImportanceDiana, "pause");
    cfg.workers = N;
    cfg.max_rounds = ROUNDS;
    cfg.seed = seed;
    cfg.wire.workers = N; // one shard per process: `:w0` is one worker
    cfg.wire.worker_timeout = 3.0;
    cfg.wire.participation = Some(format!("tau={TAU}"));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..N)
        .map(|_| {
            let addr = addr.clone();
            let fault = plan.clone();
            std::thread::spawn(move || {
                worker_connect_with(
                    &addr,
                    WorkerOpts {
                        fault: Some(fault),
                        max_retries: 0,
                        ..Default::default()
                    },
                )
            })
        })
        .collect();

    serve_on(listener, &cfg, true)
        .expect("serve_on --check-sim under pause + partial participation");
    for w in workers {
        w.join()
            .unwrap()
            .expect("paused, sampled-out worker was falsely declared dead inside the grace window");
    }
    fresh_dir(&cfg.out_dir);
}

#[test]
fn scripted_drop_uplink_severs_and_standby_replays() {
    // drop-uplink@r5:w1 — the worker hosting shard 1 computes round 5 but
    // severs instead of replying, so the round-5 uplink for its whole
    // shard group simply never arrives. The standby is promoted, replays
    // the journal (rounds 1..5), and answers round 5 live with the exact
    // bytes the deserter would have sent. diana++ here so the replay also
    // covers the sparse-downlink / model-replica path.
    let mut cfg = chaos_cfg("diana++", SamplingKind::Uniform, "drop");
    cfg.wire.workers = 2;
    cfg.wire.worker_timeout = 20.0;
    let plan = FaultPlan::parse("drop-uplink@r5:w1", 0).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let initial: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let fault = plan.clone();
            std::thread::spawn(move || {
                worker_connect_with(
                    &addr,
                    WorkerOpts {
                        fault: Some(fault),
                        ..Default::default()
                    },
                )
            })
        })
        .collect();
    let replacement = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(400));
        worker_connect(&addr)
    });

    serve_on(listener, &cfg, true).expect("serve_on --check-sim under dropped uplink");
    for w in initial {
        w.join().unwrap().expect("severing worker (clean injected exit)");
    }
    replacement.join().unwrap().expect("replacement worker");
    fresh_dir(&cfg.out_dir);
}
