//! Topology matrix: hierarchical relay trees × methods × compressors,
//! every cell asserting **bitwise identity** to the sim driver.
//!
//! The relay tier (`smx relay`, `wire::relay`) merges its children's
//! uplink frames *structurally* — constituent bodies travel verbatim
//! inside one `TAG_AGG_UPLINK` envelope, never summed or re-encoded —
//! so the server decodes exactly the bytes each worker produced, in its
//! usual per-shard slots. That is the whole topology-invariance claim:
//! flat, 2-level and 3-level trees must produce bit-for-bit identical
//! trajectories. Each cell here runs `serve_on(.., check_sim = true)`,
//! which replays the identical configuration under `Driver::Sim` and
//! fails unless final iterates AND coords_up match bitwise; since every
//! topology is held to the same sim reference, identity across depths
//! follows transitively.
//!
//! Matrix columns:
//! * **matrix-aware (`Default` compressor)** on the paper's `+` methods
//!   `dcgd+` / `diana+` / `adiana+` — the smoothness-matrix sketches;
//! * **`sa-quant`** on their baselines `dcgd` / `diana` / `adiana` (the
//!   whitened-quantization family only composes with the baselines —
//!   `check_compressor` rejects it on the `+` methods), which pushes
//!   *quantized* message content through the merge path.
//!
//! The relay-death cell kills one relay mid-run (`die_after`) with a
//! checkpoint cadence armed, so the replacement relay's rejoin exercises
//! the full catch-up stack through a relay: snapshot restore split per
//! child + journal replay + live-round uplink merge.

use smx::compress::CompressorKind;
use smx::config::ExperimentConfig;
use smx::sampling::SamplingKind;
use smx::wire::{relay_on, serve_on, worker_connect_with, RelayOpts, WorkerOpts};
use std::net::TcpListener;
use std::thread::JoinHandle;
use std::time::Duration;

fn topo_cfg(
    method: &str,
    compressor: CompressorKind,
    sampling: SamplingKind,
    scenario: &str,
) -> ExperimentConfig {
    let slug = format!("smx_topo_{scenario}_{}", method.replace('+', "p"));
    ExperimentConfig {
        dataset: "tiny".into(),
        methods: vec![method.into()],
        sampling,
        compressor,
        tau: 2.0,
        workers: 4,
        max_rounds: 40,
        target_residual: 0.0,
        record_every: 1,
        seed: 77,
        out_dir: std::env::temp_dir().join(slug),
        ..Default::default()
    }
}

/// Generous retry budget so workers ride out a relay death + replacement
/// cycle; small base so the tests stay fast.
fn resilient() -> WorkerOpts {
    WorkerOpts {
        max_retries: 20,
        retry_base_ms: 25,
        ..Default::default()
    }
}

/// Bind an ephemeral listener for a relay and run it on its own thread.
/// Returns the address workers (or deeper relays) should connect to.
fn spawn_relay(upstream: String, opts: RelayOpts) -> (String, JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || relay_on(listener, &upstream, opts));
    (addr, h)
}

fn spawn_worker(addr: String) -> JoinHandle<anyhow::Result<()>> {
    std::thread::spawn(move || worker_connect_with(&addr, resilient()))
}

/// Rebind an address the previous listener just vacated (the killed
/// relay's thread must return and drop it first).
fn bind_retry(addr: &str) -> TcpListener {
    for _ in 0..400 {
        match TcpListener::bind(addr) {
            Ok(l) => return l,
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
    panic!("could not rebind {addr} for the replacement relay");
}

fn fresh_dir(path: &std::path::Path) {
    std::fs::remove_dir_all(path).ok();
}

/// server → `relays` relays → 2 workers each.
fn run_two_level(cfg: &ExperimentConfig, relays: usize) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server_addr = listener.local_addr().unwrap().to_string();
    let mut relay_handles = Vec::new();
    let mut worker_handles = Vec::new();
    for _ in 0..relays {
        let (addr, h) = spawn_relay(
            server_addr.clone(),
            RelayOpts {
                downstream: 2,
                ..Default::default()
            },
        );
        relay_handles.push(h);
        for _ in 0..2 {
            worker_handles.push(spawn_worker(addr.clone()));
        }
    }
    serve_on(listener, cfg, true).unwrap_or_else(|e| {
        panic!("{}: 2-level serve_on --check-sim failed: {e:#}", cfg.methods[0])
    });
    for h in relay_handles {
        h.join().unwrap().expect("relay must exit cleanly at stop");
    }
    for w in worker_handles {
        w.join().unwrap().expect("worker must exit cleanly at stop");
    }
}

#[test]
fn two_level_tree_matches_sim_across_methods_and_compressors() {
    // {matrix-aware on the + methods} ∪ {sa-quant on the baselines}:
    // both exact sketches and quantized messages must survive the merge
    // verbatim. 2 relays × 2 workers × 1 shard (4 shards total).
    for (method, compressor, sampling) in [
        ("dcgd+", CompressorKind::Default, SamplingKind::Uniform),
        ("diana+", CompressorKind::Default, SamplingKind::ImportanceDiana),
        ("adiana+", CompressorKind::Default, SamplingKind::Uniform),
        ("dcgd", CompressorKind::SaQuant, SamplingKind::Uniform),
        ("diana", CompressorKind::SaQuant, SamplingKind::Uniform),
        ("adiana", CompressorKind::SaQuant, SamplingKind::Uniform),
    ] {
        let mut cfg = topo_cfg(method, compressor, sampling, "two_level");
        cfg.wire.relays = Some("2".into());
        cfg.wire.worker_timeout = 20.0;
        run_two_level(&cfg, 2);
        fresh_dir(&cfg.out_dir);
    }
}

#[test]
fn flat_two_level_and_three_level_trees_are_bitwise_identical() {
    // All three depths are asserted against the same sim reference, so
    // flat ≡ 2-level ≡ 3-level transitively. diana+ carries worker-side
    // shift state, making any topology-induced divergence compounding
    // (and thus loudly visible) rather than transient.

    // flat: 2 worker processes, 2 shards each
    let mut cfg = topo_cfg(
        "diana+",
        CompressorKind::Default,
        SamplingKind::ImportanceDiana,
        "flat",
    );
    cfg.wire.workers = 2;
    cfg.wire.worker_timeout = 20.0;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..2).map(|_| spawn_worker(addr.clone())).collect();
    serve_on(listener, &cfg, true).expect("flat serve_on --check-sim");
    for w in workers {
        w.join().unwrap().expect("flat worker");
    }
    fresh_dir(&cfg.out_dir);

    // 2-level: server → 2 relays → 2 workers each
    let mut cfg = topo_cfg(
        "diana+",
        CompressorKind::Default,
        SamplingKind::ImportanceDiana,
        "depth2",
    );
    cfg.wire.relays = Some("2".into());
    cfg.wire.worker_timeout = 20.0;
    run_two_level(&cfg, 2);
    fresh_dir(&cfg.out_dir);

    // 3-level: server → 2 relays → 2 relays each → 1 worker each; the
    // inner tier emits TAG_AGG_UPLINK frames that the outer tier must
    // flatten into its own merge (nested-aggregate path).
    let mut cfg = topo_cfg(
        "diana+",
        CompressorKind::Default,
        SamplingKind::ImportanceDiana,
        "depth3",
    );
    cfg.wire.relays = Some("2,2".into());
    cfg.wire.worker_timeout = 20.0;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server_addr = listener.local_addr().unwrap().to_string();
    let mut relay_handles = Vec::new();
    let mut worker_handles = Vec::new();
    for _ in 0..2 {
        let (mid_addr, h) = spawn_relay(
            server_addr.clone(),
            RelayOpts {
                downstream: 2,
                ..Default::default()
            },
        );
        relay_handles.push(h);
        for _ in 0..2 {
            let (leaf_addr, h) = spawn_relay(
                mid_addr.clone(),
                RelayOpts {
                    downstream: 1,
                    ..Default::default()
                },
            );
            relay_handles.push(h);
            worker_handles.push(spawn_worker(leaf_addr));
        }
    }
    serve_on(listener, &cfg, true).expect("3-level serve_on --check-sim");
    for h in relay_handles {
        h.join().unwrap().expect("relay must exit cleanly at stop");
    }
    for w in worker_handles {
        w.join().unwrap().expect("worker must exit cleanly at stop");
    }
    fresh_dir(&cfg.out_dir);
}

#[test]
fn relay_death_mid_run_recovers_bitwise_via_journal_replay() {
    // One relay vanishes on the round-6 downlink without forwarding it —
    // its workers see EOF mid-round, the server orphans the whole shard
    // group into the grace window. A replacement relay stands up on the
    // same address (exactly how an operator would recover a SIGKILLed
    // `smx relay`), rejoins, and is caught up through the snapshot
    // (checkpoint cadence 4 → restore split per child) + journal replay
    // + live round 6, while the orphaned workers reconnect to it through
    // their own backoff loops. check_sim then proves the death never
    // happened as far as the trajectory is concerned.
    let mut cfg = topo_cfg(
        "diana+",
        CompressorKind::Default,
        SamplingKind::ImportanceDiana,
        "death",
    );
    cfg.wire.relays = Some("2".into());
    cfg.wire.worker_timeout = 20.0;
    cfg.checkpoint_every = 4;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server_addr = listener.local_addr().unwrap().to_string();

    // the doomed relay: bound up front so its address is known to its
    // workers and to the replacement
    let doomed_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let doomed_addr = doomed_listener.local_addr().unwrap().to_string();
    let doomed = {
        let up = server_addr.clone();
        std::thread::spawn(move || {
            relay_on(
                doomed_listener,
                &up,
                RelayOpts {
                    downstream: 2,
                    die_after: Some(6),
                    ..Default::default()
                },
            )
        })
    };
    let replacement = {
        let up = server_addr.clone();
        let addr = doomed_addr.clone();
        std::thread::spawn(move || {
            // the address frees only when the doomed relay's thread
            // returns at round 6 and drops its listener
            let listener = bind_retry(&addr);
            relay_on(
                listener,
                &up,
                RelayOpts {
                    downstream: 2,
                    ..Default::default()
                },
            )
        })
    };
    let (healthy_addr, healthy) = spawn_relay(
        server_addr.clone(),
        RelayOpts {
            downstream: 2,
            ..Default::default()
        },
    );

    let mut workers = Vec::new();
    for _ in 0..2 {
        workers.push(spawn_worker(doomed_addr.clone()));
        workers.push(spawn_worker(healthy_addr.clone()));
    }

    serve_on(listener, &cfg, true).expect("serve_on --check-sim across a relay death");
    doomed.join().unwrap().expect("doomed relay (clean injected exit)");
    replacement.join().unwrap().expect("replacement relay");
    healthy.join().unwrap().expect("healthy relay");
    for w in workers {
        w.join().unwrap().expect("worker must survive the relay death via backoff");
    }
    fresh_dir(&cfg.out_dir);
}
