//! Seeded structure-aware fuzzing of the wire codec.
//!
//! Round-trips random frames — payload kind (f64/f32/q16/q8/q4) ×
//! sorted/unsorted/duplicated index sets × adversarial values (NaN-free
//! but ±inf-adjacent magnitudes, per-message scale extremes, denormals,
//! ±0) — and asserts:
//!
//! * `decode(encode(m))` is **bitwise lossless** for the `f64` payload
//!   (values and indices), and within the *documented* tolerance
//!   ([`Payload::max_abs_err`]) for the lossy payloads, with indices
//!   always exact;
//! * the `*_frame_len` helpers predict the encoded size exactly (they are
//!   what the in-process drivers record as measured bytes);
//! * truncated frames decode to `Err`, never panic;
//! * non-finite values round-trip bit-exactly under `f64` but are
//!   *refused* (an `Err`, not a silently-poisoned block) by the
//!   quantized payloads;
//! * arbitrary single-byte corruption decodes to `Err` *or* a valid
//!   message, never panics and never allocates unboundedly;
//! * the CRC32 frame layer ([`encode_frame`]/[`decode_frame`]) round-trips
//!   both modes, parses every truncation as "more bytes needed", and never
//!   hands back a corrupted body from a flagged frame — while an unflagged
//!   frame demonstrably does (the gap the trailer exists to close).
//!
//! The base seed comes from `SMX_FUZZ_SEED` (decimal u64; CI sets and
//! logs it — see `.github/workflows/ci.yml`), so any failure is
//! reproducible from the job log; the per-case seed is printed by the
//! property harness on failure.

use smx::compress::SparseMsg;
use smx::methods::{Downlink, Uplink};
use smx::util::prop::{forall, PropConfig};
use smx::util::rng::Rng;
use smx::wire::codec::{self, FRAME_PREFIX};
use smx::wire::transport::{crc32, decode_frame, encode_frame, FRAME_CRC_FLAG};
use smx::wire::Payload;

fn fuzz_seed() -> u64 {
    std::env::var("SMX_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x57AB1E5EED)
}

// ---- generators --------------------------------------------------------

/// NaN-free adversarial magnitudes: ±0, denormals, 1, near-overflow
/// (`cap`), mixed so per-message scales hit extremes.
fn adversarial(rng: &mut Rng, cap: f64) -> f64 {
    let mag = match rng.below(8) {
        0 => 0.0,
        1 => 5e-324,
        2 => 1e-310,
        3 => 1e-15,
        4 => 1.0,
        5 => cap,
        6 => cap / 3.0,
        _ => rng.normal(),
    };
    if rng.bernoulli(0.5) {
        -mag
    } else {
        mag
    }
}

/// f32 must stay inside f32 range for its documented relative tolerance
/// to be meaningful; every other payload is exercised ±inf-adjacent.
fn value_cap(payload: Payload) -> f64 {
    if payload == Payload::F32 {
        1e37
    } else {
        1e308
    }
}

fn random_payload(rng: &mut Rng) -> Payload {
    Payload::ALL[rng.below(Payload::ALL.len())]
}

/// Random index set over [0, dim): strictly increasing (the sketch/Top-k
/// shape → sorted-gap coding), or arbitrary order with possible
/// duplicates (→ raw-varint coding).
fn random_indices(rng: &mut Rng, dim: usize, k: usize) -> Vec<u32> {
    if rng.bernoulli(0.5) {
        rng.sample_indices(dim, k).iter().map(|&i| i as u32).collect()
    } else {
        (0..k).map(|_| rng.below(dim) as u32).collect()
    }
}

fn random_msg(rng: &mut Rng, dim: usize, payload: Payload) -> SparseMsg {
    let k = rng.below(dim + 1);
    let mut m = SparseMsg::new();
    for i in random_indices(rng, dim, k) {
        m.push(i, adversarial(rng, value_cap(payload)));
    }
    m
}

fn block_scale(vals: &[f64]) -> f64 {
    vals.iter().fold(0.0f64, |a, &v| a.max(v.abs()))
}

/// Documented per-value decode tolerance for one value block.
fn check_block(orig: &SparseMsg, dec: &SparseMsg, payload: Payload) -> Result<(), String> {
    if dec.idx != orig.idx {
        return Err(format!("{}: indices not exact", payload.name()));
    }
    if payload.is_lossless() {
        let ob: Vec<u64> = orig.val.iter().map(|v| v.to_bits()).collect();
        let db: Vec<u64> = dec.val.iter().map(|v| v.to_bits()).collect();
        if ob != db {
            return Err("f64: values not bitwise exact".into());
        }
        return Ok(());
    }
    let bound = payload.max_abs_err(block_scale(&orig.val)) * (1.0 + 1e-9);
    for (o, d) in orig.val.iter().zip(&dec.val) {
        if (o - d).abs() > bound {
            return Err(format!("{}: |{o} - {d}| > {bound}", payload.name()));
        }
    }
    Ok(())
}

fn check_dense(orig: &[f64], dec: &[f64], payload: Payload) -> Result<(), String> {
    if payload.is_lossless() {
        let ob: Vec<u64> = orig.iter().map(|v| v.to_bits()).collect();
        let db: Vec<u64> = dec.iter().map(|v| v.to_bits()).collect();
        if ob != db {
            return Err("f64: dense block not bitwise exact".into());
        }
        return Ok(());
    }
    let bound = payload.max_abs_err(block_scale(orig)) * (1.0 + 1e-9);
    for (o, d) in orig.iter().zip(dec) {
        if (o - d).abs() > bound {
            return Err(format!("{}: dense |{o} - {d}| > {bound}", payload.name()));
        }
    }
    Ok(())
}

/// A decode target pre-filled with junk, to exercise every buffer-reuse
/// branch of `get_uplink`/`get_downlink`.
fn dirty_uplink(rng: &mut Rng) -> Uplink {
    let mut up = Uplink::default();
    for _ in 0..rng.below(4) {
        up.delta.push(rng.below(100) as u32, rng.normal());
    }
    if rng.bernoulli(0.5) {
        let mut d2 = SparseMsg::new();
        d2.push(0, 1.0);
        up.delta2 = Some(d2);
    }
    up
}

fn dirty_downlink(rng: &mut Rng) -> Downlink {
    match rng.below(3) {
        0 => Downlink::Dense {
            x: vec![1.0; rng.below(5)],
            w: rng.bernoulli(0.5).then(|| vec![2.0; 3]),
        },
        1 => Downlink::Sparse {
            delta: SparseMsg::new(),
        },
        _ => Downlink::Init {
            x: vec![9.0; rng.below(5)],
        },
    }
}

// ---- round-trips -------------------------------------------------------

#[test]
fn fuzz_uplink_roundtrip_per_payload_semantics() {
    println!("SMX_FUZZ_SEED = {}", fuzz_seed());
    forall(
        PropConfig::cases(192, fuzz_seed()),
        "uplink decode(encode(m)) per payload spec",
        |rng| {
            let dim = 1 + rng.below(300);
            let payload = random_payload(rng);
            let up = Uplink {
                delta: random_msg(rng, dim, payload),
                delta2: if rng.bernoulli(0.4) {
                    Some(random_msg(rng, dim, payload))
                } else {
                    None
                },
            };
            let shard = rng.below(1 << 20);

            let mut body = Vec::new();
            codec::put_uplink(&mut body, &up, shard, payload)
                .map_err(|e| format!("{}: encode failed: {e}", payload.name()))?;
            if body.len() + FRAME_PREFIX != codec::uplink_frame_len(&up, shard, payload) {
                return Err(format!(
                    "{}: frame_len {} != encoded {}",
                    payload.name(),
                    codec::uplink_frame_len(&up, shard, payload),
                    body.len() + FRAME_PREFIX
                ));
            }

            let mut dec = dirty_uplink(rng);
            let got_shard = codec::get_uplink(&body, dim, &mut dec)
                .map_err(|e| format!("{}: decode failed: {e}", payload.name()))?;
            if got_shard != shard {
                return Err(format!("shard {got_shard} != {shard}"));
            }
            check_block(&up.delta, &dec.delta, payload)?;
            match (&up.delta2, &dec.delta2) {
                (None, None) => {}
                (Some(o), Some(d)) => check_block(o, d, payload)?,
                _ => return Err("delta2 presence flag not round-tripped".into()),
            }

            // decoding against a dim smaller than the largest index must
            // error (range check), not panic or accept
            if let Some(&mx) = up.delta.idx.iter().max() {
                let mut d2 = Uplink::default();
                if codec::get_uplink(&body, mx as usize, &mut d2).is_ok() {
                    return Err(format!("index {mx} accepted with dim {mx}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fuzz_downlink_roundtrip_per_payload_semantics() {
    forall(
        PropConfig::cases(160, fuzz_seed() ^ 0xD0),
        "downlink decode(encode(m)) per payload spec",
        |rng| {
            let dim = 1 + rng.below(200);
            let payload = random_payload(rng);
            let cap = value_cap(payload);
            let down = match rng.below(4) {
                0 => Downlink::Dense {
                    x: (0..dim).map(|_| adversarial(rng, cap)).collect(),
                    w: None,
                },
                1 => Downlink::Dense {
                    x: (0..dim).map(|_| adversarial(rng, cap)).collect(),
                    w: Some((0..dim).map(|_| adversarial(rng, cap)).collect()),
                },
                2 => Downlink::Sparse {
                    delta: random_msg(rng, dim, payload),
                },
                _ => Downlink::Init {
                    x: (0..dim).map(|_| adversarial(rng, cap)).collect(),
                },
            };

            let mut body = Vec::new();
            codec::put_downlink(&mut body, &down, payload)
                .map_err(|e| format!("{}: encode failed: {e}", payload.name()))?;
            if body.len() + FRAME_PREFIX != codec::downlink_frame_len(&down, payload) {
                return Err(format!("{}: downlink frame_len mismatch", payload.name()));
            }

            let mut dec = dirty_downlink(rng);
            codec::get_downlink(&body, dim, &mut dec)
                .map_err(|e| format!("{}: decode failed: {e}", payload.name()))?;
            match (&down, &dec) {
                (Downlink::Dense { x: ox, w: ow }, Downlink::Dense { x: dx, w: dw }) => {
                    check_dense(ox, dx, payload)?;
                    match (ow, dw) {
                        (None, None) => {}
                        (Some(o), Some(d)) => check_dense(o, d, payload)?,
                        _ => return Err("w presence not round-tripped".into()),
                    }
                }
                (Downlink::Sparse { delta: o }, Downlink::Sparse { delta: d }) => {
                    check_block(o, d, payload)?
                }
                (Downlink::Init { x: o }, Downlink::Init { x: d }) => check_dense(o, d, payload)?,
                _ => return Err("downlink kind changed in roundtrip".into()),
            }
            Ok(())
        },
    );
}

// ---- malformed frames --------------------------------------------------

/// Random sample of truncation points, always including the shortest and
/// longest prefixes (where header/trailing checks live).
fn cut_points(rng: &mut Rng, len: usize, want: usize) -> Vec<usize> {
    let mut cuts: Vec<usize> = (0..len.min(9)).collect();
    for t in len.saturating_sub(8)..len {
        cuts.push(t);
    }
    for _ in 0..want {
        if len > 0 {
            cuts.push(rng.below(len));
        }
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts
}

#[test]
fn fuzz_truncated_frames_decode_to_err() {
    forall(
        PropConfig::cases(64, fuzz_seed() ^ 0x7C),
        "every truncation decodes to Err",
        |rng| {
            let dim = 1 + rng.below(128);
            let payload = random_payload(rng);
            let up = Uplink {
                delta: random_msg(rng, dim, payload),
                delta2: rng.bernoulli(0.3).then(|| random_msg(rng, dim, payload)),
            };
            let mut body = Vec::new();
            codec::put_uplink(&mut body, &up, rng.below(64), payload).unwrap();
            for cut in cut_points(rng, body.len(), 32) {
                let mut dec = Uplink::default();
                if codec::get_uplink(&body[..cut], dim, &mut dec).is_ok() {
                    return Err(format!("uplink truncated at {cut}/{} decoded Ok", body.len()));
                }
            }

            let down = Downlink::Dense {
                x: (0..dim).map(|_| adversarial(rng, value_cap(payload))).collect(),
                w: None,
            };
            let mut dbody = Vec::new();
            codec::put_downlink(&mut dbody, &down, payload).unwrap();
            for cut in cut_points(rng, dbody.len(), 32) {
                let mut dec = dirty_downlink(rng);
                if codec::get_downlink(&dbody[..cut], dim, &mut dec).is_ok() {
                    return Err(format!(
                        "downlink truncated at {cut}/{} decoded Ok",
                        dbody.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fuzz_corrupted_frames_never_panic() {
    forall(
        PropConfig::cases(128, fuzz_seed() ^ 0xBAD),
        "byte corruption decodes to Err or a valid message, no panic",
        |rng| {
            let dim = 1 + rng.below(128);
            let payload = random_payload(rng);
            let up = Uplink {
                delta: random_msg(rng, dim, payload),
                delta2: rng.bernoulli(0.3).then(|| random_msg(rng, dim, payload)),
            };
            let mut body = Vec::new();
            codec::put_uplink(&mut body, &up, rng.below(64), payload).unwrap();
            if body.is_empty() {
                return Ok(());
            }
            for _ in 0..8 {
                let mut bad = body.clone();
                for _ in 0..1 + rng.below(4) {
                    let pos = rng.below(bad.len());
                    bad[pos] ^= (1 + rng.below(255)) as u8;
                }
                // claimed dim may also disagree with the encoder's
                let claim = 1 + rng.below(2 * dim);
                let mut dec = dirty_uplink(rng);
                let _ = codec::get_uplink(&bad, claim, &mut dec);

                // a corrupted uplink must also never decode as a downlink
                // in an uncontrolled way
                let mut ddec = dirty_downlink(rng);
                let _ = codec::get_downlink(&bad, claim, &mut ddec);
            }
            Ok(())
        },
    );
}

// ---- non-finite values --------------------------------------------------

/// Non-finite values are part of the codec contract, not outside it: the
/// `f64` payload must round-trip them bit-for-bit, while every quantized
/// payload must refuse to encode them (a NaN/±inf would otherwise poison
/// the whole block's scale and decode to silent garbage).
#[test]
fn fuzz_non_finite_values_per_payload_contract() {
    forall(
        PropConfig::cases(96, fuzz_seed() ^ 0xF1317E),
        "NaN/±inf: f64 bit-transparent, q-payloads refuse",
        |rng| {
            let dim = 2 + rng.below(128);
            let mut up = Uplink {
                delta: random_msg(rng, dim, Payload::F64),
                delta2: None,
            };
            // plant 1..4 non-finite values at random slots (growing the
            // message first if the generator rolled an empty one)
            if up.delta.idx.is_empty() {
                up.delta.push(rng.below(dim) as u32, 1.0);
            }
            let poisons = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -f64::NAN];
            for _ in 0..1 + rng.below(4) {
                let slot = rng.below(up.delta.val.len());
                up.delta.val[slot] = poisons[rng.below(poisons.len())];
            }

            // f64: exact bit transparency, same as for finite values
            let mut body = Vec::new();
            codec::put_uplink(&mut body, &up, 0, Payload::F64)
                .map_err(|e| format!("f64 refused a non-finite value: {e}"))?;
            let mut dec = dirty_uplink(rng);
            codec::get_uplink(&body, dim, &mut dec).map_err(|e| format!("f64 decode: {e}"))?;
            let ob: Vec<u64> = up.delta.val.iter().map(|v| v.to_bits()).collect();
            let db: Vec<u64> = dec.delta.val.iter().map(|v| v.to_bits()).collect();
            if ob != db {
                return Err("f64: non-finite values not bitwise exact".into());
            }

            // q16/q8/q4: encode must error (and must not have produced a
            // frame a decoder would accept as complete)
            for payload in [Payload::Q16, Payload::Q8, Payload::Q4] {
                let mut body = Vec::new();
                match codec::put_uplink(&mut body, &up, 0, payload) {
                    Err(e) => {
                        if !e.to_string().contains("non-finite") {
                            return Err(format!("{}: wrong error: {e}", payload.name()));
                        }
                    }
                    Ok(()) => {
                        return Err(format!(
                            "{}: silently encoded a non-finite block",
                            payload.name()
                        ))
                    }
                }
            }
            Ok(())
        },
    );
}

// ---- merged (relay) frames ---------------------------------------------

/// The relay tier's `TAG_AGG_UPLINK` envelope carries sibling uplink
/// bodies **verbatim** (that is the whole topology-invariance argument),
/// so the fuzz contract is structural: the merge round-trips each
/// constituent byte-for-byte in canonical shard order, re-merging an
/// aggregate flattens to the same bytes as merging flat, siblings that
/// disagree on payload encoding are refused with a clear error, and no
/// truncation or bit flip of the envelope ever panics the parser.
#[test]
fn fuzz_merged_uplink_frames_roundtrip_truncate_and_flip() {
    forall(
        PropConfig::cases(96, fuzz_seed() ^ 0xA66),
        "TAG_AGG_UPLINK: verbatim roundtrip, Err on truncation, no panic on flips",
        |rng| {
            let dim = 1 + rng.below(128);
            let payload = random_payload(rng);
            let nsib = 1 + rng.below(6);
            let shards = rng.sample_indices(200, nsib);
            let mut frames: Vec<Vec<u8>> = Vec::with_capacity(nsib);
            let mut origs: Vec<Uplink> = Vec::with_capacity(nsib);
            for &shard in &shards {
                let up = Uplink {
                    delta: random_msg(rng, dim, payload),
                    delta2: rng.bernoulli(0.3).then(|| random_msg(rng, dim, payload)),
                };
                let mut body = Vec::new();
                codec::put_uplink(&mut body, &up, shard, payload)
                    .map_err(|e| format!("{}: encode failed: {e}", payload.name()))?;
                frames.push(body);
                origs.push(up);
            }
            // merge in a scrambled order — the envelope is canonical
            // (ascending by shard) regardless of arrival order
            let mut order: Vec<usize> = (0..nsib).collect();
            for i in (1..nsib).rev() {
                order.swap(i, rng.below(i + 1));
            }
            let scrambled: Vec<&[u8]> = order.iter().map(|&i| frames[i].as_slice()).collect();
            let mut merged = Vec::new();
            codec::merge_uplinks(&mut merged, &scrambled)
                .map_err(|e| format!("merge failed: {e}"))?;

            // structural roundtrip: canonical order, bodies verbatim
            let mut parts = Vec::new();
            let got = codec::get_agg_uplink(&merged, &mut parts)
                .map_err(|e| format!("agg decode failed: {e}"))?;
            if got != payload {
                return Err(format!("payload {} != {}", got.name(), payload.name()));
            }
            if parts.len() != nsib {
                return Err(format!("{} constituents != {nsib}", parts.len()));
            }
            for (k, &(shard, start, end)) in parts.iter().enumerate() {
                if shard != shards[k] {
                    return Err(format!("constituent {k}: shard {shard} != {}", shards[k]));
                }
                if &merged[start..end] != frames[k].as_slice() {
                    return Err(format!("constituent {k}: body not carried verbatim"));
                }
                let mut dec = dirty_uplink(rng);
                let got_shard = codec::get_uplink(&merged[start..end], dim, &mut dec)
                    .map_err(|e| format!("constituent {k} decode: {e}"))?;
                if got_shard != shard {
                    return Err(format!("constituent {k}: shard {got_shard} != {shard}"));
                }
                check_block(&origs[k].delta, &dec.delta, payload)?;
            }

            // nested flatten: merging {agg(first half), rest} must emit
            // the exact bytes of the flat merge (deeper trees re-merge
            // into the same canonical envelope)
            if nsib >= 2 {
                let mid = 1 + rng.below(nsib - 1);
                let first: Vec<&[u8]> = frames[..mid].iter().map(|f| f.as_slice()).collect();
                let mut inner = Vec::new();
                codec::merge_uplinks(&mut inner, &first)
                    .map_err(|e| format!("inner merge failed: {e}"))?;
                let mut nested: Vec<&[u8]> = vec![inner.as_slice()];
                nested.extend(frames[mid..].iter().map(|f| f.as_slice()));
                let mut remerged = Vec::new();
                codec::merge_uplinks(&mut remerged, &nested)
                    .map_err(|e| format!("nested merge failed: {e}"))?;
                if remerged != merged {
                    return Err("nested merge did not flatten to the flat bytes".into());
                }

                // siblings that disagree on payload encoding are refused
                let other = Payload::ALL[(Payload::ALL
                    .iter()
                    .position(|&p| p == payload)
                    .unwrap()
                    + 1)
                    % Payload::ALL.len()];
                let mut alien = Vec::new();
                if codec::put_uplink(&mut alien, &origs[0], shards[0], other).is_ok() {
                    let mixed: Vec<&[u8]> = std::iter::once(alien.as_slice())
                        .chain(frames[1..].iter().map(|f| f.as_slice()))
                        .collect();
                    let mut out = Vec::new();
                    match codec::merge_uplinks(&mut out, &mixed) {
                        Ok(()) => return Err("mixed-payload merge accepted".into()),
                        Err(e) => {
                            if !e.to_string().contains("payload") {
                                return Err(format!("mixed-payload: wrong error: {e}"));
                            }
                        }
                    }
                }
            }

            // every truncation is an error, never a panic or an accept
            for cut in cut_points(rng, merged.len(), 32) {
                let mut parts = Vec::new();
                if codec::get_agg_uplink(&merged[..cut], &mut parts).is_ok() {
                    return Err(format!("agg truncated at {cut}/{} decoded Ok", merged.len()));
                }
            }

            // random byte corruption: parse may fail, but must not panic,
            // and any surviving constituent must itself decode sanely
            for _ in 0..8 {
                let mut bad = merged.clone();
                for _ in 0..1 + rng.below(4) {
                    let pos = rng.below(bad.len());
                    bad[pos] ^= (1 + rng.below(255)) as u8;
                }
                let mut parts = Vec::new();
                if codec::get_agg_uplink(&bad, &mut parts).is_ok() {
                    for &(_, start, end) in &parts {
                        let mut dec = dirty_uplink(rng);
                        let _ = codec::get_uplink(&bad[start..end], dim, &mut dec);
                    }
                }
            }
            Ok(())
        },
    );
}

// ---- CRC frame layer ---------------------------------------------------

#[test]
fn fuzz_crc_framing_never_yields_a_corrupted_body() {
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926, "CRC-32 check vector");
    forall(
        PropConfig::cases(160, fuzz_seed() ^ 0xC2C),
        "CRC frames reject flips; plain frames document the gap",
        |rng| {
            let body: Vec<u8> = (0..rng.below(257)).map(|_| rng.below(256) as u8).collect();
            let mut out = Vec::new();
            for crc in [false, true] {
                let wire = encode_frame(&body, crc);
                let prefix = u32::from_le_bytes(wire[..4].try_into().unwrap());
                if (prefix & FRAME_CRC_FLAG != 0) != crc {
                    return Err(format!("crc={crc}: prefix flag bit does not match mode"));
                }
                if wire.len() != 4 + body.len() + if crc { 4 } else { 0 } {
                    return Err(format!("crc={crc}: unexpected frame length"));
                }

                // exact roundtrip; receivers are self-describing
                match decode_frame(&wire, &mut out) {
                    Ok(Some((consumed, had_crc))) => {
                        if consumed != wire.len() || had_crc != crc || out != body {
                            return Err(format!("crc={crc}: roundtrip mangled the frame"));
                        }
                    }
                    other => return Err(format!("crc={crc}: roundtrip -> {other:?}")),
                }

                // every strict prefix parses as "need more bytes" — a
                // truncation is never mistaken for a frame or an error
                for cut in cut_points(rng, wire.len(), 16) {
                    match decode_frame(&wire[..cut], &mut out) {
                        Ok(None) => {}
                        other => {
                            return Err(format!("crc={crc}: truncation at {cut} -> {other:?}"))
                        }
                    }
                }
            }

            // single-bit flips over the whole flagged frame: decoding may
            // error or ask for more bytes, but it must never hand back a
            // body that differs from what was sent (a prefix-flag flip
            // legitimately decodes the intact body without verification)
            let wire = encode_frame(&body, true);
            for _ in 0..24 {
                let bit = rng.below(wire.len() * 8);
                let mut bad = wire.clone();
                bad[bit / 8] ^= 1 << (bit % 8);
                match decode_frame(&bad, &mut out) {
                    Err(_) | Ok(None) => {}
                    Ok(Some(_)) => {
                        if out != body {
                            return Err(format!(
                                "bit {bit}: flagged frame decoded a corrupted body"
                            ));
                        }
                    }
                }
            }

            // ...whereas without the trailer the same flip is silently
            // accepted — the failure mode the CRC layer exists to close
            if !body.is_empty() {
                let wire = encode_frame(&body, false);
                let bit = 32 + rng.below(body.len() * 8);
                let mut bad = wire.clone();
                bad[bit / 8] ^= 1 << (bit % 8);
                match decode_frame(&bad, &mut out) {
                    Ok(Some((n, false))) if n == bad.len() && out != body => {}
                    other => {
                        return Err(format!(
                            "plain-frame flip at bit {bit} -> {other:?} \
                             (expected a silently corrupted decode)"
                        ))
                    }
                }
            }
            Ok(())
        },
    );
}
