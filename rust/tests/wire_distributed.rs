//! Distributed-driver integration tests.
//!
//! * Under the lossless `f64` payload, the distributed driver (loopback
//!   and TCP) must produce iterates **bitwise identical** to the sim
//!   driver, for dense-downlink methods, ADIANA's two-message uplink, and
//!   DIANA++'s sparse downlink — at one process per shard *and* with
//!   several shards multiplexed per process.
//! * Measured `bytes_up`/`bytes_down` recorded by the sim driver equal
//!   the bytes the distributed driver actually framed (procs = n).
//! * Lossy payloads track the `f64` trajectory on a1a within the
//!   tolerances documented in `wire/mod.rs`.
//! * Chaos: a worker killed mid-run and replaced (rejoin + journal
//!   replay) — or absorbed by the survivor (grace-window reassignment +
//!   reserve-half adoption), or restored from a checkpoint snapshot after
//!   journal truncation — still yields a final model bitwise identical to
//!   the sim driver under the f64 payload.
//!
//! Every run is constructed through the [`Session`] front door.

use smx::config::ExperimentConfig;
use smx::coordinator::{DistTransport, Driver, EngineFactory, RunConfig, Session};
use smx::experiments::runner::{self, run_config};
use smx::methods::MethodSpec;
use smx::runtime::native::NativeEngine;
use smx::runtime::GradEngine;
use smx::sampling::SamplingKind;
use smx::wire::{serve_on, worker_connect, worker_connect_with, Payload, WorkerOpts};
use std::sync::Arc;
use std::time::Duration;

fn tiny_cfg() -> ExperimentConfig {
    ExperimentConfig {
        dataset: "tiny".into(),
        workers: 4,
        max_rounds: 40,
        target_residual: 0.0,
        record_every: 1,
        seed: 77,
        out_dir: std::env::temp_dir().join("smx_wire_test"),
        ..Default::default()
    }
}

fn factory_for(prep: &runner::Prepared, mu: f64) -> EngineFactory {
    let shards = prep.shards.clone();
    Arc::new(move |i| Box::new(NativeEngine::from_shard(&shards[i], mu)) as Box<dyn GradEngine>)
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn loopback_f64_accounting_and_sparse_downlink() {
    // Cross-driver iterate identity for the dense-downlink methods lives
    // in the matrix test (`tests/driver_matrix.rs`); this test keeps the
    // coverage that is unique to the wire layer: diana++'s sparse
    // downlink (lossless-only, model replicas), and the measured
    // `bytes_up`/`bytes_down` equality between the sim's frame-length
    // accounting and the bytes the distributed driver actually framed.
    let cfg = tiny_cfg();
    // need_global=true so the same Prepared also serves diana++
    let prep = runner::prepare_with(&cfg, true).unwrap();
    let n = prep.shards.len();
    let run_cfg = run_config(&cfg);
    assert_eq!(run_cfg.payload, Payload::F64);

    for (name, sampling, tau) in [
        ("diana+", SamplingKind::ImportanceDiana, 2.0),
        ("diana++", SamplingKind::Uniform, 2.0), // sparse downlink
    ] {
        let mut spec = MethodSpec::new(name, tau, sampling, cfg.mu, vec![0.0; prep.sm.dim]);
        spec.practical_adiana = cfg.practical_adiana;

        let r_sim = Session::new(spec.clone())
            .prepared(&prep)
            .driver(Driver::Sim)
            .run_config(run_cfg.clone())
            .run()
            .unwrap();

        for procs in [n, 2] {
            let r_dist = Session::new(spec.clone())
                .prepared(&prep)
                .driver(Driver::Distributed {
                    transport: DistTransport::Loopback { procs },
                })
                .engine_factory(factory_for(&prep, cfg.mu))
                .run_config(run_cfg.clone())
                .run()
                .unwrap();

            assert_eq!(
                bits(&r_sim.final_x),
                bits(&r_dist.final_x),
                "{name} (procs={procs}): iterates diverged from run_sim"
            );
            let (ls, ld) = (
                r_sim.records.last().unwrap(),
                r_dist.records.last().unwrap(),
            );
            assert_eq!(ls.coords_up, ld.coords_up, "{name}: coords_up diverged");
            assert_eq!(ls.bits_up, ld.bits_up, "{name}: modeled bits diverged");
            assert_eq!(
                ls.bytes_up, ld.bytes_up,
                "{name} (procs={procs}): sim-accounted bytes_up != measured"
            );
            if procs == n {
                // one process per shard: the downlink fan-out matches the
                // sim's per-worker broadcast model exactly
                assert_eq!(
                    ls.bytes_down, ld.bytes_down,
                    "{name}: sim-accounted bytes_down != measured"
                );
            }
            assert!(ld.bytes_up > 0 && ld.bytes_down > 0);
        }
    }
}

#[test]
fn tcp_serve_check_sim_roundtrips() {
    // Full TCP path in-process: serve_on an ephemeral port, two worker
    // "processes" (threads running the real worker_connect entry point,
    // each hosting 2 of the 4 shards). --check-sim semantics assert
    // bitwise identity against run_sim inside serve_on.
    let mut cfg = tiny_cfg();
    cfg.methods = vec!["diana+".into()];
    cfg.sampling = SamplingKind::ImportanceDiana;
    cfg.tau = 2.0;
    cfg.max_rounds = 25;
    cfg.wire.workers = 2;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let workers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || worker_connect(&addr))
        })
        .collect();
    serve_on(listener, &cfg, true).expect("serve_on with check-sim");
    for w in workers {
        w.join().unwrap().expect("worker failed");
    }
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn chaos_worker_death_and_rejoin_is_bitwise_identical() {
    // One of two worker processes is killed mid-round (it drops its
    // connection right after receiving the round-6 downlink, without
    // replying — observably a SIGKILL at that instant). A replacement is
    // already parked as a standby; the server hands it the orphaned shard
    // set via the same Hello handshake and streams the replay journal, so
    // it lands in a bitwise-identical trajectory. `check_sim` inside
    // serve_on asserts final iterates AND coords_up against run_sim.
    let mut cfg = tiny_cfg();
    cfg.methods = vec!["diana+".into()];
    cfg.sampling = SamplingKind::ImportanceDiana;
    cfg.tau = 2.0;
    cfg.max_rounds = 40;
    cfg.wire.workers = 2;
    cfg.wire.worker_timeout = 20.0;
    cfg.out_dir = std::env::temp_dir().join("smx_wire_chaos_rejoin");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let dying = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            worker_connect_with(
                &addr,
                WorkerOpts {
                    die_after: Some(6),
                    ..Default::default()
                },
            )
        })
    };
    let survivor = {
        let addr = addr.clone();
        std::thread::spawn(move || worker_connect(&addr))
    };
    // the replacement connects after the initial pair has its
    // assignments; it parks as a standby until shards orphan
    let replacement = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(400));
        worker_connect(&addr)
    });

    serve_on(listener, &cfg, true).expect("serve_on --check-sim under worker death + rejoin");
    dying.join().unwrap().expect("dying worker (clean injected exit)");
    survivor.join().unwrap().expect("surviving worker");
    replacement.join().unwrap().expect("replacement worker");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn chaos_reassignment_to_survivor_is_bitwise_identical() {
    // Same death, but no replacement ever arrives: after the grace window
    // (0.5 s here) the server deals the orphaned shards to the survivor
    // via TAG_ADOPT + journal replay. The survivor promotes its reserve
    // worker halves (built at round 0 and kept for exactly this), replays
    // them forward, and finishes the run hosting every shard — still
    // bitwise identical to run_sim.
    let mut cfg = tiny_cfg();
    cfg.methods = vec!["diana+".into()];
    cfg.sampling = SamplingKind::Uniform;
    cfg.tau = 2.0;
    cfg.max_rounds = 30;
    cfg.wire.workers = 2;
    cfg.wire.worker_timeout = 0.5;
    cfg.out_dir = std::env::temp_dir().join("smx_wire_chaos_adopt");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let dying = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            worker_connect_with(
                &addr,
                WorkerOpts {
                    die_after: Some(4),
                    ..Default::default()
                },
            )
        })
    };
    let survivor = std::thread::spawn(move || worker_connect(&addr));

    serve_on(listener, &cfg, true)
        .expect("serve_on --check-sim under worker death + shard reassignment");
    dying.join().unwrap().expect("dying worker (clean injected exit)");
    survivor.join().unwrap().expect("surviving worker (with adopted shards)");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn chaos_snapshot_resume_is_bitwise_identical() {
    // Checkpoint cadence 3, death after downlink 8: the server requests
    // snapshots after rounds 3 and 6; each commits during the following
    // round's gather (workers answer the request before touching the next
    // downlink, and TCP preserves order), truncating the journal to the
    // post-snapshot suffix. When the worker dies at round 8, the rounds
    // up to 6 are *gone* from the journal — the replacement can only
    // catch up by restoring the round-6 state blobs (TAG_RESTORE) and
    // replaying the ≤2 retained rounds. `--expect-restore` on the
    // replacement asserts the restore actually happened, and `check_sim`
    // inside serve_on asserts the final iterates AND coords_up are
    // bitwise identical to the sim driver.
    let mut cfg = tiny_cfg();
    cfg.methods = vec!["diana+".into()];
    cfg.sampling = SamplingKind::ImportanceDiana;
    cfg.tau = 2.0;
    cfg.max_rounds = 40;
    cfg.checkpoint_every = 3;
    cfg.wire.workers = 2;
    cfg.wire.worker_timeout = 20.0;
    cfg.out_dir = std::env::temp_dir().join("smx_wire_chaos_snapshot");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let dying = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            worker_connect_with(
                &addr,
                WorkerOpts {
                    die_after: Some(8),
                    ..Default::default()
                },
            )
        })
    };
    let survivor = {
        let addr = addr.clone();
        std::thread::spawn(move || worker_connect(&addr))
    };
    let replacement = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(400));
        worker_connect_with(
            &addr,
            WorkerOpts {
                expect_restore: true,
                ..Default::default()
            },
        )
    });

    serve_on(listener, &cfg, true)
        .expect("serve_on --check-sim under worker death + snapshot-resume");
    dying.join().unwrap().expect("dying worker (clean injected exit)");
    survivor.join().unwrap().expect("surviving worker");
    replacement
        .join()
        .unwrap()
        .expect("replacement worker (must have been snapshot-restored)");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn lossy_payloads_track_f64_on_a1a() {
    // Documented tolerances (wire/mod.rs): after a few hundred rounds the
    // lossy trajectories stay within an additive tolerance of the f64
    // residual (quantization error is relative to per-message magnitude,
    // so the perturbations contract along with the iterates).
    let cfg = ExperimentConfig {
        dataset: "a1a".into(),
        methods: vec!["diana+".into()],
        max_rounds: 200,
        target_residual: 0.0,
        record_every: 200,
        seed: 42,
        out_dir: std::env::temp_dir().join("smx_wire_a1a"),
        ..Default::default()
    };
    let prep = runner::prepare(&cfg).unwrap();

    let residual_at = |payload: Payload| -> f64 {
        let mut run_cfg: RunConfig = run_config(&cfg);
        run_cfg.payload = payload;
        let spec = MethodSpec::new(
            "diana+",
            2.0,
            SamplingKind::Uniform,
            cfg.mu,
            vec![0.0; prep.sm.dim],
        );
        let r = Session::new(spec)
            .prepared(&prep)
            .driver(Driver::Distributed {
                // 8 processes hosting ~13 shards each
                transport: DistTransport::Loopback { procs: 8 },
            })
            .engine_factory(factory_for(&prep, cfg.mu))
            .run_config(run_cfg)
            .run()
            .unwrap();
        r.final_residual()
    };

    let r64 = residual_at(Payload::F64);
    assert!(r64.is_finite() && r64 < 1.0, "f64 reference stalled: {r64}");
    let r32 = residual_at(Payload::F32);
    let r16 = residual_at(Payload::Q16);
    let tol32 = (0.5 * r64).max(1e-6);
    let tol16 = (0.5 * r64).max(1e-4);
    assert!(
        (r32 - r64).abs() <= tol32,
        "f32 drifted: {r32:.3e} vs f64 {r64:.3e} (tol {tol32:.1e})"
    );
    assert!(
        (r16 - r64).abs() <= tol16,
        "q16 drifted: {r16:.3e} vs f64 {r64:.3e} (tol {tol16:.1e})"
    );
}
