//! Property tests locking down the kernel layer:
//!
//! 1. **Cross-arm bitwise identity** — every dispatch arm of the explicit
//!    SIMD layer (`linalg::simd`) must be *bitwise identical* to the
//!    scalar blocked arm, for every kernel, on adversarial inputs
//!    (denormals, ±0, 1e300-scale magnitudes, remainder tails 0–7, empty,
//!    length-1, misaligned slices). Both arms run in the same process via
//!    the explicit `*_at(level, …)` entry points.
//! 2. **Oracle parity** — the blocked/SIMD kernels agree with naive
//!    sequential reference loops: bitwise for elementwise kernels, and
//!    within the classic `n·eps·Σ|terms|` reassociation bound for
//!    reductions.
//! 3. **Representation parity** — the dense and low-rank PSD-root
//!    representations (including the fused low-rank apply) agree on
//!    random dense and sparse inputs (the whiten/decompress paths).

#![allow(clippy::needless_range_loop)]

use smx::linalg::dense::Mat;
use smx::linalg::simd::{self, Level};
use smx::linalg::sparse::Csr;
use smx::linalg::vector;
use smx::linalg::PsdRoot;
use smx::util::prop::{forall, PropConfig};
use smx::util::rng::Rng;

// ---- generators --------------------------------------------------------

/// Magnitude palette stressing IEEE edge behavior. `cap` bounds the
/// magnitude so oracle comparisons can avoid intermediate overflow
/// (products of two palette values stay finite for cap = 1e150).
fn adversarial(rng: &mut Rng, cap: f64) -> f64 {
    let mag = match rng.below(8) {
        0 => 0.0,
        1 => 5e-324,        // smallest subnormal
        2 => 1e-310,        // subnormal
        3 => 1e-150,
        4 => 1.0,
        5 => cap,
        6 => cap / 3.0,
        _ => rng.normal(),
    };
    if rng.bernoulli(0.5) {
        -mag
    } else {
        mag
    }
}

/// Lengths hitting every remainder tail 0–7 around the 4-lane (and
/// 8-lane AVX-512) block sizes, plus empty/one/bigger.
fn edge_len(rng: &mut Rng) -> usize {
    const EDGES: [usize; 18] = [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 65];
    match rng.below(EDGES.len() + 2) {
        i if i < EDGES.len() => EDGES[i],
        _ => rng.below(if cfg!(miri) { 64 } else { 1024 }) + 1,
    }
}

/// A vector of `n + off` adversarial values returned with its offset, so
/// `&buf[off..off + n]` exercises all four 8-byte alignment phases of a
/// 32-byte SIMD lane.
fn adversarial_vec(rng: &mut Rng, n: usize, off: usize, cap: f64) -> Vec<f64> {
    (0..n + off).map(|_| adversarial(rng, cap)).collect()
}

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, cap: f64) -> Csr {
    let mut t = Vec::new();
    if cols > 0 {
        let density = rng.uniform();
        for r in 0..rows {
            for c in 0..cols {
                if rng.uniform() < density {
                    t.push((r, c, adversarial(rng, cap)));
                }
            }
        }
    }
    Csr::from_triplets(rows, cols, t)
}

/// Bit pattern with NaNs canonicalized: whether a result is NaN is
/// value-determined (so still compared exactly), but NaN *payloads* are
/// not guaranteed stable across evaluations (Miri randomizes them by
/// design), so payload bits must not participate in equality.
fn canon_bits(v: f64) -> u64 {
    if v.is_nan() {
        0x7ff8_0000_0000_0000
    } else {
        v.to_bits()
    }
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|&v| canon_bits(v)).collect()
}

// ---- 1. cross-arm bitwise identity ------------------------------------

#[test]
fn prop_simd_arms_bitwise_match_scalar_arm_vector_kernels() {
    let levels = Level::available();
    println!("dispatch arms under test: {levels:?} (active: {:?})", simd::active());
    forall(
        PropConfig::cases(128, 0x51D0),
        "cross-arm bitwise identity (vector kernels)",
        |rng| {
            let n = edge_len(rng);
            let off = rng.below(4);
            // ±inf-adjacent magnitudes are fine here: both arms perform
            // the identical op sequence, so overflow to ±inf (and whether
            // an inf−inf reduction yields NaN) is identical; NaN payloads
            // are canonicalized by canon_bits before comparison
            let a_buf = adversarial_vec(rng, n, off, 1e300);
            let b_buf = adversarial_vec(rng, n, off, 1e300);
            let (a, b) = (&a_buf[off..], &b_buf[off..]);
            let alpha = adversarial(rng, 1e3);
            let beta = adversarial(rng, 1e3);

            let d_ref = canon_bits(simd::dot_at(Level::Scalar, a, b));
            let s_ref = canon_bits(simd::dist2_at(Level::Scalar, a, b));
            let w_ref = canon_bits(simd::wnorm2_diag_at(Level::Scalar, a, b));
            let mut y_ref = b.to_vec();
            simd::axpy_at(Level::Scalar, alpha, a, &mut y_ref);
            let mut l_ref = vec![0.0; n];
            simd::lincomb_into_at(Level::Scalar, alpha, a, beta, b, &mut l_ref);
            let (mut ra_ref, mut rb_ref) = (a.to_vec(), b.to_vec());
            simd::rot2_at(Level::Scalar, alpha, beta, &mut ra_ref, &mut rb_ref);

            for &lvl in &levels {
                if canon_bits(simd::dot_at(lvl, a, b)) != d_ref {
                    return Err(format!("dot {lvl:?} != scalar at n={n} off={off}"));
                }
                if canon_bits(simd::dist2_at(lvl, a, b)) != s_ref {
                    return Err(format!("dist2 {lvl:?} != scalar at n={n} off={off}"));
                }
                if canon_bits(simd::wnorm2_diag_at(lvl, a, b)) != w_ref {
                    return Err(format!("wnorm2_diag {lvl:?} != scalar at n={n} off={off}"));
                }
                let mut y = b.to_vec();
                simd::axpy_at(lvl, alpha, a, &mut y);
                if bits(&y) != bits(&y_ref) {
                    return Err(format!("axpy {lvl:?} != scalar at n={n} off={off}"));
                }
                let mut l = vec![0.0; n];
                simd::lincomb_into_at(lvl, alpha, a, beta, b, &mut l);
                if bits(&l) != bits(&l_ref) {
                    return Err(format!("lincomb {lvl:?} != scalar at n={n} off={off}"));
                }
                let (mut ra, mut rb) = (a.to_vec(), b.to_vec());
                simd::rot2_at(lvl, alpha, beta, &mut ra, &mut rb);
                if bits(&ra) != bits(&ra_ref) || bits(&rb) != bits(&rb_ref) {
                    return Err(format!("rot2 {lvl:?} != scalar at n={n} off={off}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_arms_bitwise_match_scalar_arm_matvec_kernels() {
    let levels = Level::available();
    forall(
        PropConfig::cases(96, 0x51D1),
        "cross-arm bitwise identity (dense + CSR matvec)",
        |rng| {
            // dense: rows/cols sweep the 4-row and 4-col remainders
            let rows = rng.below(12);
            let cols = rng.below(12) + usize::from(rng.bernoulli(0.2)) * rng.below(64);
            let data = adversarial_vec(rng, rows * cols, 0, 1e300);
            let x = adversarial_vec(rng, cols, 0, 1e300);
            let mut out_ref = vec![0.0; rows];
            simd::mat_matvec_into_at(Level::Scalar, &data, rows, cols, &x, &mut out_ref);

            // CSR: includes empty rows, empty matrix, nnz tails 0–7
            let a = random_csr(rng, rows, cols, 1e300);
            let y = adversarial_vec(rng, rows, 0, 1e300);
            let mut mv_ref = vec![0.0; rows];
            simd::csr_matvec_into_at(Level::Scalar, &a.indptr, &a.indices, &a.values, &x, &mut mv_ref);
            let mut tv_ref = vec![0.0; cols];
            simd::csr_tmatvec_into_at(Level::Scalar, &a.indptr, &a.indices, &a.values, &y, &mut tv_ref);

            for &lvl in &levels {
                let mut out = vec![0.0; rows];
                simd::mat_matvec_into_at(lvl, &data, rows, cols, &x, &mut out);
                if bits(&out) != bits(&out_ref) {
                    return Err(format!("mat matvec {lvl:?} != scalar at {rows}x{cols}"));
                }
                let mut mv = vec![0.0; rows];
                simd::csr_matvec_into_at(lvl, &a.indptr, &a.indices, &a.values, &x, &mut mv);
                if bits(&mv) != bits(&mv_ref) {
                    return Err(format!(
                        "csr matvec {lvl:?} != scalar at {rows}x{cols} nnz={}",
                        a.nnz()
                    ));
                }
                let mut tv = vec![0.0; cols];
                simd::csr_tmatvec_into_at(lvl, &a.indptr, &a.indices, &a.values, &y, &mut tv);
                if bits(&tv) != bits(&tv_ref) {
                    return Err(format!(
                        "csr tmatvec {lvl:?} != scalar at {rows}x{cols} nnz={}",
                        a.nnz()
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---- 2. oracle parity --------------------------------------------------

// scalar references (the pre-optimization sequential kernels)

fn ref_dot(a: &[f64], b: &[f64]) -> f64 {
    (0..a.len()).map(|i| a[i] * b[i]).sum()
}

fn ref_axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

fn ref_matvec(m: &Mat, x: &[f64]) -> Vec<f64> {
    (0..m.rows)
        .map(|r| (0..m.cols).map(|c| m[(r, c)] * x[c]).sum())
        .collect()
}

fn ref_csr_matvec(a: &Csr, x: &[f64]) -> Vec<f64> {
    (0..a.rows)
        .map(|r| {
            let (idx, val) = a.row_entries(r);
            (0..idx.len()).map(|k| val[k] * x[idx[k] as usize]).sum()
        })
        .collect()
}

fn ref_csr_tmatvec(a: &Csr, y: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.cols];
    for r in 0..a.rows {
        let (idx, val) = a.row_entries(r);
        for k in 0..idx.len() {
            out[idx[k] as usize] += y[r] * val[k];
        }
    }
    out
}

fn close(a: f64, b: f64, scale: f64) -> bool {
    (a - b).abs() <= 1e-12 * scale.max(1.0)
}

/// Reassociation bound for comparing two summation orders of the same
/// terms: each order's error is ≤ (n−1)·eps·Σ|tᵢ| in the worst case, so
/// the difference is ≤ 2(n−1)·eps·Σ|tᵢ|; 4(n+4) leaves slack for the
/// per-term products' own rounding.
fn reassoc_ok(fast: f64, naive: f64, n: usize, abs_sum: f64) -> bool {
    (fast - naive).abs() <= 4.0 * (n as f64 + 4.0) * f64::EPSILON * abs_sum.max(f64::MIN_POSITIVE)
}

#[test]
fn prop_reduction_kernels_within_reassociation_bound_of_naive() {
    forall(
        PropConfig::cases(96, 0xD07E),
        "dot/dist2/wnorm2 vs naive oracle on edge values",
        |rng| {
            let n = edge_len(rng);
            let off = rng.below(4);
            // cap 1e100: wnorm2's triple products w·x·x then stay ≤ 1e300
            // and sums of ≤ 1024 of them stay finite, so the bound is
            // meaningful for every reduction here (dot's pairwise products
            // are even smaller); the 1e300-scale overflow behavior is
            // covered by the cross-arm bitwise tests above
            let a_buf = adversarial_vec(rng, n, off, 1e100);
            let b_buf = adversarial_vec(rng, n, off, 1e100);
            let (a, b) = (&a_buf[off..], &b_buf[off..]);

            let abs_dot: f64 = (0..n).map(|i| (a[i] * b[i]).abs()).sum();
            if !reassoc_ok(vector::dot(a, b), ref_dot(a, b), n, abs_dot) {
                return Err(format!("dot reassociation bound violated at n={n}"));
            }

            // squared terms are non-negative, so d_naive doubles as Σ|tᵢ|
            let d_naive: f64 = (0..n).map(|i| (a[i] - b[i]) * (a[i] - b[i])).sum();
            if !reassoc_ok(vector::dist2(a, b), d_naive, n, d_naive) {
                return Err(format!("dist2 reassociation bound violated at n={n}"));
            }

            let w_naive: f64 = (0..n).map(|i| b[i] * a[i] * a[i]).sum();
            let abs_w: f64 = (0..n).map(|i| (b[i] * a[i] * a[i]).abs()).sum();
            if !reassoc_ok(vector::wnorm2_diag(a, b), w_naive, n, abs_w) {
                return Err(format!("wnorm2_diag reassociation bound violated at n={n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_elementwise_kernels_bitwise_match_naive() {
    forall(
        PropConfig::cases(96, 0xE1E),
        "axpy/lincomb/rot2/tmatvec vs naive oracle, bitwise",
        |rng| {
            let n = edge_len(rng);
            let off = rng.below(4);
            let a_buf = adversarial_vec(rng, n, off, 1e150);
            let b_buf = adversarial_vec(rng, n, off, 1e150);
            let (a, b) = (&a_buf[off..], &b_buf[off..]);
            let alpha = adversarial(rng, 1e3);
            let beta = adversarial(rng, 1e3);

            let mut y1 = b.to_vec();
            let mut y2 = b.to_vec();
            vector::axpy(alpha, a, &mut y1);
            ref_axpy(alpha, a, &mut y2);
            if bits(&y1) != bits(&y2) {
                return Err(format!("axpy not bitwise identical to naive at n={n}"));
            }

            let mut l1 = vec![0.0; n];
            vector::lincomb_into(alpha, a, beta, b, &mut l1);
            let l2: Vec<f64> = (0..n).map(|i| alpha * a[i] + beta * b[i]).collect();
            if bits(&l1) != bits(&l2) {
                return Err(format!("lincomb not bitwise identical to naive at n={n}"));
            }

            let (mut ra, mut rb) = (a.to_vec(), b.to_vec());
            vector::rot2(alpha, beta, &mut ra, &mut rb);
            let ra2: Vec<f64> = (0..n).map(|i| alpha * a[i] - beta * b[i]).collect();
            let rb2: Vec<f64> = (0..n).map(|i| beta * a[i] + alpha * b[i]).collect();
            if bits(&ra) != bits(&ra2) || bits(&rb) != bits(&rb2) {
                return Err(format!("rot2 not bitwise identical to naive at n={n}"));
            }

            // CSR tmatvec scatter: elementwise adds in row order, so it
            // too must match the naive oracle bitwise (cap 1e150 + ≤ 16
            // rows keeps every per-column sum finite)
            let rows = rng.below(16);
            let cols = rng.below(16);
            let csr = random_csr(rng, rows, cols, 1e150);
            let yv = adversarial_vec(rng, rows, 0, 1e150);
            let mut tv = vec![0.0; cols];
            smx::linalg::simd::csr_tmatvec_into(
                &csr.indptr,
                &csr.indices,
                &csr.values,
                &yv,
                &mut tv,
            );
            if bits(&tv) != bits(&ref_csr_tmatvec(&csr, &yv)) {
                return Err(format!(
                    "csr tmatvec not bitwise identical to naive at {rows}x{cols} nnz={}",
                    csr.nnz()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_vector_kernels_match_references() {
    forall(
        PropConfig::cases(64, 0xD07),
        "dot/axpy/dist2 parity",
        |rng| {
            let n = rng.below(257);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let scale = ref_dot(&a, &a).abs() + ref_dot(&b, &b).abs();

            if !close(vector::dot(&a, &b), ref_dot(&a, &b), scale) {
                return Err(format!("dot mismatch at n={n}"));
            }

            let alpha = rng.normal();
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            vector::axpy(alpha, &a, &mut y1);
            ref_axpy(alpha, &a, &mut y2);
            if y1 != y2 {
                return Err(format!("axpy not bitwise identical at n={n}"));
            }

            let d2 = vector::dist2(&a, &b);
            let d2_ref: f64 = (0..n).map(|i| (a[i] - b[i]) * (a[i] - b[i])).sum();
            if !close(d2, d2_ref, scale) {
                return Err(format!("dist2 mismatch at n={n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_dense_kernels_match_references() {
    forall(
        PropConfig::cases(48, 0xDE45),
        "dense matvec/matmul/gram parity",
        |rng| {
            let rows = 1 + rng.below(24);
            let cols = 1 + rng.below(24);
            let m = Mat::from_rows(
                (0..rows)
                    .map(|_| (0..cols).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();

            let fast = m.matvec(&x);
            let slow = ref_matvec(&m, &x);
            for r in 0..rows {
                if !close(fast[r], slow[r], slow[r].abs() + 1.0) {
                    return Err(format!("matvec {rows}x{cols} row {r}"));
                }
            }

            let b = Mat::from_rows(
                (0..cols)
                    .map(|_| (0..rows).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let prod = m.matmul(&b);
            for i in 0..rows {
                for j in 0..rows {
                    let s: f64 = (0..cols).map(|k| m[(i, k)] * b[(k, j)]).sum();
                    if !close(prod[(i, j)], s, s.abs() + 1.0) {
                        return Err(format!("matmul {rows}x{cols} at ({i},{j})"));
                    }
                }
            }

            let g = m.gram();
            for i in 0..cols {
                for j in 0..cols {
                    let s: f64 = (0..rows).map(|r| m[(r, i)] * m[(r, j)]).sum();
                    if !close(g[(i, j)], s, s.abs() + 1.0) {
                        return Err(format!("gram {rows}x{cols} at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_csr_kernels_match_references() {
    forall(
        PropConfig::cases(48, 0xC52),
        "CSR matvec/tmatvec parity",
        |rng| {
            let rows = 1 + rng.below(30);
            let cols = 1 + rng.below(30);
            let density = 0.05 + rng.uniform() * 0.6;
            let mut t = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    if rng.uniform() < density {
                        t.push((r, c, rng.normal()));
                    }
                }
            }
            let a = Csr::from_triplets(rows, cols, t);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();

            let mv = a.matvec(&x);
            let mv_ref = ref_csr_matvec(&a, &x);
            for r in 0..rows {
                if !close(mv[r], mv_ref[r], mv_ref[r].abs() + 1.0) {
                    return Err(format!("csr matvec {rows}x{cols} row {r} (nnz={})", a.nnz()));
                }
            }
            if a.tmatvec(&y) != ref_csr_tmatvec(&a, &y) {
                return Err(format!("csr tmatvec {rows}x{cols} not bitwise identical"));
            }
            Ok(())
        },
    );
}

// ---- 3. PSD-root representation parity --------------------------------

#[test]
fn prop_dense_and_lowrank_roots_agree_on_sparse_inputs() {
    forall(
        PropConfig::cases(32, 0x10A7),
        "dense vs low-rank apply_pow_sparse_into",
        |rng| {
            // L = c·AᵀA + μI with m < d, both representations
            let m = 2 + rng.below(5);
            let d = m + 1 + rng.below(10);
            let a = Mat::from_rows(
                (0..m)
                    .map(|_| (0..d).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let c = 0.1 + rng.uniform();
            let mu = 1e-4 + rng.uniform() * 1e-2;
            let mut l = a.gram();
            l.scale(c);
            l.add_diag(mu);
            let dense = PsdRoot::from_dense(&l);
            let lowrank = PsdRoot::from_lowrank_ridge(&a, &a.gram_t(), c, mu);

            // random sparse input
            let nnz = 1 + rng.below(d);
            let mut picked: Vec<usize> = rng.sample_indices(d, nnz);
            picked.sort_unstable();
            let idx: Vec<u32> = picked.iter().map(|&i| i as u32).collect();
            let val: Vec<f64> = (0..nnz).map(|_| rng.normal()).collect();

            let mut out_d = vec![0.0; d];
            let mut out_l = vec![0.0; d];
            for p in [0.5, -0.5] {
                dense.apply_pow_sparse_into(p, &idx, &val, &mut out_d);
                lowrank.apply_pow_sparse_into(p, &idx, &val, &mut out_l);
                let scale: f64 = out_d.iter().map(|v| v.abs()).fold(0.0, f64::max);
                for j in 0..d {
                    if (out_d[j] - out_l[j]).abs() > 1e-8 * scale.max(1.0) {
                        return Err(format!(
                            "p={p} d={d} m={m} coord {j}: dense {} vs low-rank {}",
                            out_d[j], out_l[j]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_lowrank_apply_matches_dense_root_on_dense_inputs() {
    forall(
        PropConfig::cases(32, 0xF05D),
        "fused low-rank apply_pow vs dense root (whiten path)",
        |rng| {
            let m = 2 + rng.below(5);
            let d = m + 1 + rng.below(12);
            let a = Mat::from_rows(
                (0..m)
                    .map(|_| (0..d).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let c = 0.1 + rng.uniform();
            let mu = 1e-4 + rng.uniform() * 1e-2;
            let mut l = a.gram();
            l.scale(c);
            l.add_diag(mu);
            let dense = PsdRoot::from_dense(&l);
            let lowrank = PsdRoot::from_lowrank_ridge(&a, &a.gram_t(), c, mu);

            // dense input with some exact zeros (the fused pass skips
            // zero rows of the Qᵀx accumulation)
            let x: Vec<f64> = (0..d)
                .map(|_| if rng.bernoulli(0.2) { 0.0 } else { rng.normal() })
                .collect();
            let mut out_d = vec![0.0; d];
            let mut out_f = vec![0.0; d];
            let mut coeff = Vec::new();
            for p in [1.0, 0.5, -0.5, -1.0] {
                dense.apply_pow_into(p, &x, &mut out_d);
                lowrank.apply_pow_fused_into(p, &x, &mut out_f, &mut coeff);
                let scale: f64 = out_d.iter().map(|v| v.abs()).fold(0.0, f64::max);
                for j in 0..d {
                    if (out_d[j] - out_f[j]).abs() > 1e-8 * scale.max(1.0) {
                        return Err(format!(
                            "p={p} d={d} m={m} coord {j}: dense {} vs fused {}",
                            out_d[j], out_f[j]
                        ));
                    }
                }
                // the routed entry point must hit the same fused kernel
                let mut out_routed = vec![0.0; d];
                lowrank.apply_pow_into_with(p, &x, &mut out_routed, &mut coeff);
                if bits(&out_routed) != bits(&out_f) {
                    return Err(format!("apply_pow_into_with not routed through fused (p={p})"));
                }
            }
            Ok(())
        },
    );
}
