//! Property tests: the blocked linalg kernels must agree with scalar
//! reference loops to 1e-12 across random shapes, and the dense and
//! low-rank PSD-root representations must agree on random sparse inputs
//! (the server decompression path).

#![allow(clippy::needless_range_loop)]

use smx::linalg::dense::Mat;
use smx::linalg::sparse::Csr;
use smx::linalg::vector;
use smx::linalg::PsdRoot;
use smx::util::prop::{forall, PropConfig};

// scalar references (the pre-optimization kernels)

fn ref_dot(a: &[f64], b: &[f64]) -> f64 {
    (0..a.len()).map(|i| a[i] * b[i]).sum()
}

fn ref_axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

fn ref_matvec(m: &Mat, x: &[f64]) -> Vec<f64> {
    (0..m.rows)
        .map(|r| (0..m.cols).map(|c| m[(r, c)] * x[c]).sum())
        .collect()
}

fn ref_csr_matvec(a: &Csr, x: &[f64]) -> Vec<f64> {
    (0..a.rows)
        .map(|r| {
            let (idx, val) = a.row_entries(r);
            (0..idx.len()).map(|k| val[k] * x[idx[k] as usize]).sum()
        })
        .collect()
}

fn ref_csr_tmatvec(a: &Csr, y: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.cols];
    for r in 0..a.rows {
        let (idx, val) = a.row_entries(r);
        for k in 0..idx.len() {
            out[idx[k] as usize] += y[r] * val[k];
        }
    }
    out
}

fn close(a: f64, b: f64, scale: f64) -> bool {
    (a - b).abs() <= 1e-12 * scale.max(1.0)
}

#[test]
fn prop_blocked_vector_kernels_match_references() {
    forall(
        PropConfig {
            cases: 64,
            base_seed: 0xD07,
        },
        "dot/axpy/dist2 parity",
        |rng| {
            let n = rng.below(257);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let scale = ref_dot(&a, &a).abs() + ref_dot(&b, &b).abs();

            if !close(vector::dot(&a, &b), ref_dot(&a, &b), scale) {
                return Err(format!("dot mismatch at n={n}"));
            }

            let alpha = rng.normal();
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            vector::axpy(alpha, &a, &mut y1);
            ref_axpy(alpha, &a, &mut y2);
            if y1 != y2 {
                return Err(format!("axpy not bitwise identical at n={n}"));
            }

            let d2 = vector::dist2(&a, &b);
            let d2_ref: f64 = (0..n).map(|i| (a[i] - b[i]) * (a[i] - b[i])).sum();
            if !close(d2, d2_ref, scale) {
                return Err(format!("dist2 mismatch at n={n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_dense_kernels_match_references() {
    forall(
        PropConfig {
            cases: 48,
            base_seed: 0xDE45,
        },
        "dense matvec/matmul/gram parity",
        |rng| {
            let rows = 1 + rng.below(24);
            let cols = 1 + rng.below(24);
            let m = Mat::from_rows(
                (0..rows)
                    .map(|_| (0..cols).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();

            let fast = m.matvec(&x);
            let slow = ref_matvec(&m, &x);
            for r in 0..rows {
                if !close(fast[r], slow[r], slow[r].abs() + 1.0) {
                    return Err(format!("matvec {rows}x{cols} row {r}"));
                }
            }

            let b = Mat::from_rows(
                (0..cols)
                    .map(|_| (0..rows).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let prod = m.matmul(&b);
            for i in 0..rows {
                for j in 0..rows {
                    let s: f64 = (0..cols).map(|k| m[(i, k)] * b[(k, j)]).sum();
                    if !close(prod[(i, j)], s, s.abs() + 1.0) {
                        return Err(format!("matmul {rows}x{cols} at ({i},{j})"));
                    }
                }
            }

            let g = m.gram();
            for i in 0..cols {
                for j in 0..cols {
                    let s: f64 = (0..rows).map(|r| m[(r, i)] * m[(r, j)]).sum();
                    if !close(g[(i, j)], s, s.abs() + 1.0) {
                        return Err(format!("gram {rows}x{cols} at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_csr_kernels_match_references() {
    forall(
        PropConfig {
            cases: 48,
            base_seed: 0xC52,
        },
        "CSR matvec/tmatvec parity",
        |rng| {
            let rows = 1 + rng.below(30);
            let cols = 1 + rng.below(30);
            let density = 0.05 + rng.uniform() * 0.6;
            let mut t = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    if rng.uniform() < density {
                        t.push((r, c, rng.normal()));
                    }
                }
            }
            let a = Csr::from_triplets(rows, cols, t);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();

            let mv = a.matvec(&x);
            let mv_ref = ref_csr_matvec(&a, &x);
            for r in 0..rows {
                if !close(mv[r], mv_ref[r], mv_ref[r].abs() + 1.0) {
                    return Err(format!("csr matvec {rows}x{cols} row {r} (nnz={})", a.nnz()));
                }
            }
            if a.tmatvec(&y) != ref_csr_tmatvec(&a, &y) {
                return Err(format!("csr tmatvec {rows}x{cols} not bitwise identical"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_and_lowrank_roots_agree_on_sparse_inputs() {
    forall(
        PropConfig {
            cases: 32,
            base_seed: 0x10A7,
        },
        "dense vs low-rank apply_pow_sparse_into",
        |rng| {
            // L = c·AᵀA + μI with m < d, both representations
            let m = 2 + rng.below(5);
            let d = m + 1 + rng.below(10);
            let a = Mat::from_rows(
                (0..m)
                    .map(|_| (0..d).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let c = 0.1 + rng.uniform();
            let mu = 1e-4 + rng.uniform() * 1e-2;
            let mut l = a.gram();
            l.scale(c);
            l.add_diag(mu);
            let dense = PsdRoot::from_dense(&l);
            let lowrank = PsdRoot::from_lowrank_ridge(&a, &a.gram_t(), c, mu);

            // random sparse input
            let nnz = 1 + rng.below(d);
            let mut picked: Vec<usize> = rng.sample_indices(d, nnz);
            picked.sort_unstable();
            let idx: Vec<u32> = picked.iter().map(|&i| i as u32).collect();
            let val: Vec<f64> = (0..nnz).map(|_| rng.normal()).collect();

            let mut out_d = vec![0.0; d];
            let mut out_l = vec![0.0; d];
            for p in [0.5, -0.5] {
                dense.apply_pow_sparse_into(p, &idx, &val, &mut out_d);
                lowrank.apply_pow_sparse_into(p, &idx, &val, &mut out_l);
                let scale: f64 = out_d.iter().map(|v| v.abs()).fold(0.0, f64::max);
                for j in 0..d {
                    if (out_d[j] - out_l[j]).abs() > 1e-8 * scale.max(1.0) {
                        return Err(format!(
                            "p={p} d={d} m={m} coord {j}: dense {} vs low-rank {}",
                            out_d[j], out_l[j]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
