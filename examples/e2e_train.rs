//! End-to-end three-layer validation (DESIGN.md §3, row E2E).
//!
//! Trains regularized logistic regression on an a8a-scale workload
//! (22 696 points, d = 123, n = 8 workers) with DIANA+ through the
//! **full stack**:
//!
//!   L1 Pallas kernel → L2 JAX model → AOT HLO text (`make artifacts`)
//!   → PJRT CPU executables → threaded Rust coordinator (one OS thread
//!   per worker, SPSC ring-buffer channels, matrix-aware sparse uplinks).
//!
//! Logs the loss curve + communication volume; numbers are recorded in
//! EXPERIMENTS.md. Run with:
//!
//!     make artifacts && cargo run --release --example e2e_train
//!
//! Flags: --rounds N (default 300) --tau F (default 4) --engine native
//! to cross-check against the pure-Rust oracle; --jsonl PATH streams the
//! residual curve as JSON lines while the run is still going (a
//! `Session` round observer).

use smx::config::ExperimentConfig;
use smx::coordinator::{Driver, EngineFactory, JsonlObserver, RunConfig, Session};
use smx::experiments::runner;
use smx::methods::MethodSpec;
use smx::runtime::artifact::Manifest;
use smx::runtime::native::NativeEngine;
use smx::runtime::pjrt::PjrtEngine;
use smx::runtime::GradEngine;
use smx::sampling::SamplingKind;
use smx::util::cli::Args;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    smx::util::log::init_from_env();
    let args = Args::from_env(false);
    let rounds = args.usize_or("rounds", 300);
    let tau = args.f64_or("tau", 4.0);
    let engine = args.str_or("engine", "pjrt");

    let cfg = ExperimentConfig {
        dataset: "a8a".into(),
        tau,
        max_rounds: rounds,
        target_residual: 0.0,
        record_every: (rounds / 30).max(1),
        ..Default::default()
    };

    println!("== e2e_train: a8a-scale DIANA+ through the three-layer stack ==");
    let t_prep = Instant::now();
    let prep = runner::prepare(&cfg)?;
    println!(
        "problem: {} points, d={}, n={} workers, m_i={}  (prep {:.1}s)",
        prep.dataset.num_points(),
        prep.sm.dim,
        prep.sm.n(),
        prep.shards[0].num_points(),
        t_prep.elapsed().as_secs_f64()
    );

    let spec = MethodSpec::new(
        "diana+",
        tau,
        SamplingKind::ImportanceDiana,
        cfg.mu,
        vec![0.0; prep.sm.dim],
    );
    let run_cfg = RunConfig {
        max_rounds: rounds,
        record_every: cfg.record_every,
        ..Default::default()
    };

    let shards = prep.shards.clone();
    let mu = cfg.mu;
    let factory: EngineFactory = match engine.as_str() {
        "pjrt" => {
            let manifest = Arc::new(Manifest::load(&smx::runtime::artifact::default_dir())?);
            println!(
                "engine: PJRT (artifacts: {:?})",
                manifest.shapes()
            );
            Arc::new(move |i| {
                Box::new(
                    PjrtEngine::from_shard(&manifest, &shards[i], mu)
                        .expect("pjrt engine (did you run `make artifacts`?)"),
                ) as Box<dyn GradEngine>
            })
        }
        _ => {
            println!("engine: native (pure-Rust oracle)");
            Arc::new(move |i| Box::new(NativeEngine::from_shard(&shards[i], mu)) as Box<dyn GradEngine>)
        }
    };

    // the full stack behind the one front door: threaded driver, engines
    // built inside worker threads, metrics optionally streamed live
    let mut session = Session::new(spec)
        .prepared(&prep)
        .driver(Driver::Threaded)
        .engine_factory(factory)
        .run_config(run_cfg);
    if let Some(path) = args.get("jsonl") {
        println!("streaming residual curve to {path} (one JSON object per record)");
        session = session.observer(JsonlObserver::create(path)?);
    }
    let t_run = Instant::now();
    let result = session.run()?;
    let wall = t_run.elapsed().as_secs_f64();

    // loss curve (re-evaluated on the recorded rounds' final state only at
    // the end — the coordinator tracks residual; we log both)
    println!("\nround   residual        coords_up      wall(s)");
    for rec in &result.records {
        println!(
            "{:>5}   {:<14.4e} {:>12}   {:>8.2}",
            rec.round, rec.residual, rec.coords_up, rec.wall_secs
        );
    }
    let f_final = prep.problem.loss(&result.final_x);
    let last = result.records.last().unwrap();
    println!("\n=== e2e summary ===");
    println!("engine                {engine}");
    println!("rounds                {}", result.rounds_run);
    println!("wall time             {wall:.2}s  ({:.1} rounds/s)", result.rounds_run as f64 / wall);
    println!("final loss f(x)       {:.9}  (f* = {:.9})", f_final, prep.f_star);
    println!("final residual        {:.3e}", result.final_residual());
    println!(
        "uplink volume         {} coords ({:.2} MB at f64+idx)",
        last.coords_up,
        last.bits_up as f64 / 8e6
    );
    println!(
        "dense-equivalent      {} coords  ⇒ compression {:.1}x",
        result.rounds_run as u64 * prep.sm.n() as u64 * prep.sm.dim as u64,
        (result.rounds_run as f64 * prep.sm.n() as f64 * prep.sm.dim as f64)
            / last.coords_up as f64
    );
    println!("\nphase breakdown:\n{}", result.phases.report());
    Ok(())
}
