//! τ-sweep demo (the Figure-3/4 story): sparsification is free until τ
//! drops below a threshold; DIANA+ keeps the iteration complexity while
//! slashing worker→server communication.
//!
//!     cargo run --release --example tau_sweep [-- --dataset phishing]

use smx::config::ExperimentConfig;
use smx::experiments::runner;
use smx::sampling::SamplingKind;
use smx::util::cli::Args;

fn main() -> anyhow::Result<()> {
    smx::util::log::init_from_env();
    let args = Args::from_env(false);
    let cfg = ExperimentConfig {
        dataset: args.str_or("dataset", "phishing"),
        max_rounds: args.usize_or("rounds", 60_000),
        target_residual: 1e-9,
        record_every: 100,
        ..Default::default()
    };
    let prep = runner::prepare(&cfg)?;
    let d = prep.sm.dim as f64;

    let taus = [1.0, 2.0, 4.0, 8.0, (d / 4.0).floor(), d];
    let eps = 1e-8;
    println!(
        "DIANA+ on {} (d = {}, n = {}): rounds & uplink coords to residual ≤ {eps:.0e}\n",
        cfg.dataset, prep.sm.dim, prep.sm.n()
    );
    println!("tau        importance: rounds / coords        uniform: rounds / coords");
    for &tau in &taus {
        let tau = tau.max(1.0);
        let imp = runner::run_one(&prep, &cfg, "diana+", SamplingKind::ImportanceDiana, tau)?;
        let uni = runner::run_one(&prep, &cfg, "diana+", SamplingKind::Uniform, tau)?;
        let fmt = |r: &smx::coordinator::RunResult| match (r.rounds_to(eps), r.coords_to(eps)) {
            (Some(it), Some(c)) => format!("{it:>7} / {c:>11}"),
            _ => format!("   — ({:.1e})", r.final_residual()),
        };
        println!("{tau:<8}   {:<32}   {}", fmt(&imp), fmt(&uni));
    }
    println!(
        "\nreading: rounds should stay ~flat down to a τ threshold (smaller for\n\
         importance sampling), so coords-to-target *decreases* as τ shrinks —\n\
         the paper's 'communication is almost free' regime (Figures 3-4)."
    );
    Ok(())
}
