//! Importance sampling demo (the Figure-1 story on one dataset): how the
//! optimal probabilities (eq. 19) concentrate communication on the
//! high-smoothness coordinates, and what that buys in convergence.
//!
//!     cargo run --release --example importance_sampling [-- --dataset a1a]

use smx::config::ExperimentConfig;
use smx::experiments::runner;
use smx::sampling::SamplingKind;
use smx::util::cli::Args;

fn main() -> anyhow::Result<()> {
    smx::util::log::init_from_env();
    let args = Args::from_env(false);
    let cfg = ExperimentConfig {
        dataset: args.str_or("dataset", "phishing"),
        tau: 1.0,
        max_rounds: args.usize_or("rounds", 40_000),
        target_residual: 1e-10,
        record_every: 200,
        ..Default::default()
    };

    let prep = runner::prepare(&cfg)?;
    let loc = &prep.sm.locals[0];

    // show the probability profiles for worker 0
    let uni = SamplingKind::Uniform.build(&loc.diag, cfg.tau, cfg.mu, prep.sm.n());
    let imp = SamplingKind::ImportanceDiana.build(&loc.diag, cfg.tau, cfg.mu, prep.sm.n());
    let mut order: Vec<usize> = (0..loc.diag.len()).collect();
    order.sort_by(|&a, &b| loc.diag[b].partial_cmp(&loc.diag[a]).unwrap());
    println!("worker 0 probability profile (top/bottom smoothness coordinates):");
    println!("  coord      L_jj          p_uniform   p_importance(19)");
    for &j in order.iter().take(5).chain(order.iter().rev().take(3)) {
        println!(
            "  {j:>5}   {:<12.4e}  {:<10.5}  {:<10.5}",
            loc.diag[j], uni.p[j], imp.p[j]
        );
    }
    println!(
        "  ω (uniform) = {:.1}   ω_max (importance) = {:.1}",
        uni.omega(),
        imp.omega()
    );
    println!(
        "  𝓛̃ (uniform) = {:.4e}   𝓛̃ (importance) = {:.4e}  (ratio {:.1}x)",
        uni.tilde_l(&loc.diag),
        imp.tilde_l(&loc.diag),
        uni.tilde_l(&loc.diag) / imp.tilde_l(&loc.diag)
    );

    println!("\nconvergence comparison (DIANA+, τ = 1):");
    let r_uni = runner::run_one(&prep, &cfg, "diana+", SamplingKind::Uniform, cfg.tau)?;
    let r_imp = runner::run_one(&prep, &cfg, "diana+", SamplingKind::ImportanceDiana, cfg.tau)?;
    let eps = 1e-8;
    for (name, r) in [("uniform", &r_uni), ("importance", &r_imp)] {
        match r.rounds_to(eps) {
            Some(it) => println!("  {name:<12} {it:>8} rounds to {eps:.0e}"),
            None => println!(
                "  {name:<12} not reached in {} (final {:.2e})",
                r.rounds_run,
                r.final_residual()
            ),
        }
    }
    Ok(())
}
