//! Quickstart: build a small distributed logistic-regression problem,
//! run DIANA (standard sparsification) vs DIANA+ (matrix-smoothness-aware,
//! Algorithm 2) and print the communication savings.
//!
//!     cargo run --release --example quickstart

use smx::config::ExperimentConfig;
use smx::experiments::runner;
use smx::sampling::SamplingKind;

fn main() -> anyhow::Result<()> {
    smx::util::log::init_from_env();

    // a mushrooms-scale problem: 8124 points, d = 112, 12 workers
    let cfg = ExperimentConfig {
        dataset: "mushrooms".into(),
        tau: 1.0, // each worker sends ~1 coordinate per round
        max_rounds: 30_000,
        target_residual: 1e-10,
        record_every: 100,
        ..Default::default()
    };

    println!("preparing problem (synthetic LibSVM-like '{}')...", cfg.dataset);
    let prep = runner::prepare(&cfg)?;
    println!(
        "  d = {}, n = {} workers, m_i = {} points each",
        prep.sm.dim,
        prep.sm.n(),
        prep.shards[0].num_points()
    );
    println!(
        "  L = {:.3e}, L_max = {:.3e}, nu1 = {:.1} (heterogeneous diag ⇒ importance sampling wins)",
        prep.sm.l,
        prep.sm.l_max,
        prep.sm.nu_s(1.0)
    );

    println!("\nrunning DIANA  (standard sparsification, uniform)...");
    let diana = runner::run_one(&prep, &cfg, "diana", SamplingKind::Uniform, cfg.tau)?;
    println!("running DIANA+ (matrix-aware, importance sampling eq. 19)...");
    let diana_plus = runner::run_one(
        &prep,
        &cfg,
        "diana+",
        SamplingKind::ImportanceDiana,
        cfg.tau,
    )?;

    let eps = 1e-8;
    println!("\n=== results (target residual {eps:.0e}) ===");
    for (name, r) in [("DIANA", &diana), ("DIANA+", &diana_plus)] {
        match (r.rounds_to(eps), r.coords_to(eps)) {
            (Some(it), Some(c)) => {
                println!("{name:<8} {it:>8} rounds   {c:>12} coordinates uplinked")
            }
            _ => println!(
                "{name:<8} did not reach target in {} rounds (residual {:.2e})",
                r.rounds_run,
                r.final_residual()
            ),
        }
    }
    if let (Some(a), Some(b)) = (diana.rounds_to(eps), diana_plus.rounds_to(eps)) {
        println!(
            "\nDIANA+ speedup: {:.1}x fewer rounds at identical per-round communication",
            a as f64 / b as f64
        );
    }
    Ok(())
}
