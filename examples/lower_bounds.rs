//! Lower-bound laboratory (Appendix C / Figure 5): measure the
//! variance-vs-communication trade-off of linear sparsifiers on Gaussian
//! vectors and verify Theorem 14's bound α + β ≥ 1 empirically.
//!
//!     cargo run --release --example lower_bounds

use smx::compress::lowerbound;
use smx::util::rng::Rng;

fn main() {
    let d = 1000;
    let mut rng = Rng::new(2026);

    println!("random q-sparsification of N(0,1)^{d} (optimal linear scheme, Thm 15):");
    println!("  q      α (≈1−q)   β          α+β (≥1)   α·4^(b/d)");
    let mut worst_linear = f64::MAX;
    for &q in &[0.05, 0.1, 0.25, 0.5, 0.75, 0.9] {
        let p = lowerbound::random_sparsification_point(d, q, &mut rng);
        worst_linear = worst_linear.min(p.linear_lb);
        println!(
            "  {:<5.2} {:<10.4} {:<10.4} {:<10.4} {:<12.4}",
            q, p.alpha, p.beta, p.linear_lb, p.general_up
        );
    }
    println!("  ⇒ min(α+β) = {worst_linear:.4} — Theorem 14 demands ≥ 1 for linear compressors");
    println!(
        "  ⇒ and stays ≤ 1 + H₂(q)/32 ≈ {:.4} at worst (near-optimality, §C.5)",
        1.0 + lowerbound::h2(0.5) / 32.0
    );

    println!("\ngreedy top-k (nonlinear comparator):");
    println!("  k/d    α          β          α+β        α·4^(b/d)");
    for &k in &[50usize, 150, 300, 500, 800] {
        let p = lowerbound::topk_point(d, k, &mut rng);
        println!(
            "  {:<5.2} {:<10.4} {:<10.4} {:<10.4} {:<12.4}",
            p.param, p.alpha, p.beta, p.linear_lb, p.general_up
        );
    }
    println!(
        "\nreading: top-k dips *below* α+β = 1 (it adapts the sketch to x, so the\n\
         linear bound does not apply), while every random-sparsification point\n\
         sits on/above it — exactly the separation Figure 5 plots. The general\n\
         uncertainty principle α·4^(b/d) ≥ 1 is far looser for both."
    );
}
