//! Appendix-B single-node methods demo: RCD as sketched gradient descent.
//! Compares 'NSync, SkGD, CGD+ and the §7 greedy extension on one node.
//!
//!     cargo run --release --example single_node [-- --dataset phishing]

use smx::data;
use smx::linalg::vector;
use smx::methods::prox::Prox;
use smx::methods::single::{cgd_plus::CgdPlus, greedy::GreedyCgdPlus, nsync::NSync, skgd::SkGd, SingleMethod};
use smx::objective::logreg::LogReg;
use smx::objective::smoothness::build_local;
use smx::sampling::IndependentSampling;
use smx::util::cli::Args;
use smx::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let name = args.str_or("dataset", "phishing");
    let steps = args.usize_or("steps", 6000);
    let tau = args.usize_or("tau", 4);

    let raw = data::load_or_synth(&name, None, 42)?;
    let (global, _) = raw.prepare(1, 42);
    let d = global.dim();
    let obj = LogReg::new(global.a.clone(), global.b.clone(), 1e-3);
    let loc = build_local(&global.a, 1e-3);
    println!(
        "single node: {} ({} pts, d={}), tau={tau}, {steps} steps\n",
        name,
        global.num_points(),
        d
    );

    let sampling = IndependentSampling::uniform(d, tau as f64);
    let mut methods: Vec<Box<dyn SingleMethod>> = vec![
        Box::new(NSync::new(&loc, sampling.clone(), vec![0.0; d])),
        Box::new(NSync::serial_optimal(&loc, vec![0.0; d])),
        Box::new(SkGd::new(&loc, sampling.clone(), vec![0.0; d])),
        Box::new(CgdPlus::new(&loc, sampling.clone(), Prox::None, vec![0.0; d])),
        Box::new(GreedyCgdPlus::new(&loc, tau, vec![0.0; d])),
    ];
    let labels = ["nsync", "nsync-serial-opt", "skgd", "cgd+", "greedy-cgd+ (§7)"];

    let f0 = obj.loss(&vec![0.0; d]);
    println!("{:<18} {:>12} {:>14}", "method", "f(x)-ish", "‖∇f(x)‖");
    for (m, label) in methods.iter_mut().zip(labels) {
        let mut rng = Rng::new(7);
        for _ in 0..steps {
            m.step(&obj, &mut rng);
        }
        println!(
            "{label:<18} {:>12.6} {:>14.3e}",
            obj.loss(m.x()),
            vector::norm(&obj.grad(m.x()))
        );
    }
    println!("\n(f at x0 = {f0:.6}; all methods use theory stepsizes from 𝓛̄ = λ_max(P̄∘L))");
    Ok(())
}
