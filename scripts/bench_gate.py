#!/usr/bin/env python3
"""Bench regression gate: compare BENCH_hotpath.json against a committed
baseline.

Usage:
    bench_gate.py CURRENT BASELINE [--threshold 0.25]
    bench_gate.py CURRENT BASELINE --seed

Policy (CI):
  * rows whose name starts with ``round e2e`` or ``relay merge`` are
    **gated**: a median wall-clock regression beyond the threshold
    (default +25%) fails the job;
  * every other row present in both files only **warns** beyond the
    threshold (micro-kernel rows are noisy on shared runners);
  * an unseeded baseline (missing file, or ``{"seeded": false}``) makes
    the gate a no-op with a notice — seed it from the first
    toolchain-equipped run with ``--seed`` and commit the result.

The baseline format is intentionally tiny and diff-friendly::

    {"seeded": true, "rows": {"<row name>": <median_ns>, ...}}
"""

import json
import sys


# end-to-end rows plus the relay tier's frame-merge hot path; tuple so
# str.startswith matches any of them
GATED_PREFIX = ("round e2e", "relay merge")


def load_rows(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    return {r["name"]: float(r["median_ns"]) for r in doc["results"]}


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    flags = [a for a in argv[1:] if a.startswith("--")]
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    current_path, baseline_path = args
    seed = "--seed" in flags
    threshold = 0.25
    for f in flags:
        if f.startswith("--threshold="):
            threshold = float(f.split("=", 1)[1])

    current = load_rows(current_path)

    if seed:
        doc = {"seeded": True, "rows": current}
        with open(baseline_path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"seeded {baseline_path} with {len(current)} rows")
        return 0

    try:
        with open(baseline_path, encoding="utf-8") as f:
            baseline_doc = json.load(f)
    except FileNotFoundError:
        print(f"bench gate: no baseline at {baseline_path} — skipping "
              f"(seed it with: bench_gate.py {current_path} {baseline_path} --seed)")
        return 0
    if not baseline_doc.get("seeded"):
        print("bench gate: baseline not seeded yet — skipping "
              "(run bench_gate.py with --seed on a toolchain-equipped host "
              "and commit benchmarks/baseline.json)")
        return 0

    baseline = {k: float(v) for k, v in baseline_doc["rows"].items()}
    failures, warnings = [], []
    for name in sorted(current):
        if name not in baseline:
            continue
        base, cur = baseline[name], current[name]
        if base <= 0:
            continue
        ratio = cur / base - 1.0
        line = f"{name}: {base:.0f}ns -> {cur:.0f}ns ({ratio:+.1%})"
        gated = name.startswith(GATED_PREFIX)
        if ratio > threshold:
            (failures if gated else warnings).append(line)
        elif gated:
            print(f"ok    {line}")

    for w in warnings:
        print(f"WARN  {w}")
    for f_ in failures:
        print(f"FAIL  {f_}")
    missing = [n for n in baseline if n not in current]
    if missing:
        print(f"note: {len(missing)} baseline row(s) absent from this run "
              f"(renamed or removed): {', '.join(sorted(missing)[:5])}...")

    if failures:
        print(f"\nbench gate: {len(failures)} gated regression(s) beyond "
              f"+{threshold:.0%}")
        return 1
    print(f"\nbench gate: OK ({len(warnings)} warning(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
