#!/usr/bin/env python
"""Render the paper's figures from the CSVs that `smx figures` writes.

Usage:
    python scripts/plot_figures.py [--results results] [--out results/plots]

Produces one PNG per figure/dataset, matching the paper's layout:
  Figure 1/2: residual vs iteration (log y)
  Figure 3:   residual vs iteration, one curve per τ
  Figure 4:   residual vs coordinates sent to server
  Figure 5:   α+β and α·4^{b/d} scatter for random/top-k sparsification
"""

import argparse
import csv
import os
from collections import defaultdict

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt


def read_curves(path):
    """label -> (rounds, residuals, coords)."""
    curves = defaultdict(lambda: ([], [], []))
    with open(path) as f:
        for row in csv.DictReader(f):
            c = curves[row["label"]]
            c[0].append(int(row["round"]))
            c[1].append(float(row["residual"]))
            c[2].append(int(row["coords_up"]))
    return curves


def plot_residual(path, out, x_axis="round", title=""):
    curves = read_curves(path)
    plt.figure(figsize=(5, 4))
    for label, (rounds, res, coords) in sorted(curves.items()):
        xs = rounds if x_axis == "round" else coords
        plt.semilogy(xs, res, label=label, linewidth=1.2)
    plt.xlabel("iteration" if x_axis == "round" else "coordinates sent to server")
    plt.ylabel(r"$\|x^k - x^*\|^2 / \|x^0 - x^*\|^2$")
    plt.title(title, fontsize=10)
    plt.legend(fontsize=7)
    plt.grid(True, alpha=0.3)
    plt.tight_layout()
    plt.savefig(out, dpi=130)
    plt.close()
    print(f"wrote {out}")


def plot_fig5(path, out):
    pts = defaultdict(lambda: ([], [], []))
    with open(path) as f:
        for row in csv.DictReader(f):
            p = pts[row["scheme"]]
            p[0].append(float(row["beta"]))
            p[1].append(float(row["alpha"]))
            p[2].append(float(row["bits"]))
    plt.figure(figsize=(5, 4))
    colors = {"random": "gold", "topk": "darkorange"}
    for scheme, (betas, alphas, _) in pts.items():
        plt.scatter(betas, alphas, s=14, marker="^", label=scheme, color=colors.get(scheme))
    # lower bounds
    import numpy as np

    beta = np.linspace(0.001, 1.05, 200)
    plt.plot(beta, 1 - beta, "b--", label=r"linear bound $\alpha+\beta\geq 1$ (Thm 14)")
    plt.plot(beta, 4.0 ** (-32 * beta), "r--", label=r"general UP $\alpha \cdot 4^{b/d}\geq 1$")
    plt.xlabel(r"$\beta = b/(32d)$")
    plt.ylabel(r"$\alpha$ (squared error fraction)")
    plt.ylim(-0.02, 1.05)
    plt.legend(fontsize=7)
    plt.grid(True, alpha=0.3)
    plt.tight_layout()
    plt.savefig(out, dpi=130)
    plt.close()
    print(f"wrote {out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--out", default="results/plots")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for fname in sorted(os.listdir(args.results)):
        path = os.path.join(args.results, fname)
        if not fname.endswith(".csv"):
            continue
        stem = fname[:-4]
        if fname.startswith(("fig1_", "fig2_", "train_")):
            plot_residual(path, os.path.join(args.out, stem + ".png"), "round", stem)
        elif fname.startswith("fig34_"):
            plot_residual(path, os.path.join(args.out, stem + "_iters.png"), "round", stem + " (Fig 3)")
            plot_residual(path, os.path.join(args.out, stem + "_coords.png"), "coords", stem + " (Fig 4)")
        elif fname == "fig5.csv":
            plot_fig5(path, os.path.join(args.out, "fig5.png"))


if __name__ == "__main__":
    main()
