#!/usr/bin/env bash
# Run the hot-path micro-bench suite and capture the perf trajectory.
#
# `benches/hotpath.rs` writes BENCH_hotpath.json (median/min/p95 ns per
# row) into the repo root; this wrapper builds in release, runs it, and
# keeps a timestamped copy under benchmarks/ so successive PRs can diff:
#
#   ./scripts/bench_trajectory.sh
#   python3 -m json.tool BENCH_hotpath.json | less
#
set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench hotpath "$@"

if [[ -f BENCH_hotpath.json ]]; then
    mkdir -p benchmarks
    stamp=$(date -u +%Y%m%dT%H%M%SZ)
    cp BENCH_hotpath.json "benchmarks/hotpath_${stamp}.json"
    echo "saved benchmarks/hotpath_${stamp}.json"
else
    echo "error: bench did not produce BENCH_hotpath.json" >&2
    exit 1
fi
