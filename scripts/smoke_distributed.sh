#!/usr/bin/env bash
# Multi-process smoke test for the wire subsystem, two legs:
#
#  1. steady state — one `smx serve` coordinator and two `smx worker`
#     processes on the synthetic tiny dataset (8 shards, 4 per worker
#     process) for a few rounds;
#  2. chaos — same topology plus a third (replacement) worker process;
#     worker 1 drops its connection right after receiving the round-5
#     downlink (`--die-after 5`, observably a SIGKILL at that instant),
#     the replacement rejoins via the Hello handshake and replays the
#     journal.
#
# Both legs pass `--check-sim`, which makes the server re-run the
# identical configuration through the in-process `run_sim` driver and
# exit nonzero unless the distributed iterates are bitwise identical — so
# the whole codec/transport/poller/runtime stack, including the recovery
# path, is asserted by the server's exit code.
#
#   BIN=target/release/smx PORT=4973 bash scripts/smoke_distributed.sh
set -u

BIN=${BIN:-target/release/smx}
PORT=${PORT:-4973}
OUT=${OUT:-$(mktemp -d)}

run_leg() {
  local name=$1
  local addr=$2
  shift 2
  # `timeout` bounds the whole run so a worker that dies before connecting
  # (serve would then block in accept() forever) fails the job fast
  # instead of hanging until the CI-level timeout.
  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" serve --dataset tiny --workers 8 --methods diana+ \
    --sampling importance-diana --tau 2 --max-rounds 30 \
    --listen "$addr" --wire-workers 2 --out-dir "$OUT" --check-sim "$@" &
  local serve_pid=$!

  local rc=0
  local w_pids=()
  case $name in
    steady)
      "$BIN" worker --connect "$addr" &
      w_pids+=("$!")
      "$BIN" worker --connect "$addr" &
      w_pids+=("$!")
      ;;
    chaos)
      "$BIN" worker --connect "$addr" --die-after 5 &
      w_pids+=("$!")
      "$BIN" worker --connect "$addr" &
      w_pids+=("$!")
      # replacement: parks as a standby until worker 1's shards orphan,
      # then rejoins with a journal replay
      (sleep 1 && "$BIN" worker --connect "$addr") &
      w_pids+=("$!")
      ;;
  esac

  wait "$serve_pid" || rc=1
  local i=1
  for pid in "${w_pids[@]}"; do
    wait "$pid" || { echo "[$name] worker $i failed" >&2; rc=1; }
    i=$((i + 1))
  done

  if [ "$rc" -ne 0 ]; then
    echo "distributed smoke FAILED ($name leg)" >&2
    exit 1
  fi
  echo "distributed smoke OK ($name leg: bitwise identical to run_sim)"
}

run_leg steady "127.0.0.1:$PORT"
run_leg chaos "127.0.0.1:$((PORT + 1))" --worker-timeout 60
