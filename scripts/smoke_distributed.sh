#!/usr/bin/env bash
# Multi-process smoke test for the wire subsystem: spawn one `smx serve`
# coordinator and two `smx worker` processes on the synthetic tiny dataset
# (8 shards, 4 per worker process) for a few rounds. `--check-sim` makes
# the server re-run the identical configuration through the in-process
# `run_sim` driver and exit nonzero unless the distributed iterates are
# bitwise identical — the whole codec/transport/runtime stack is asserted
# by the server's exit code.
#
#   BIN=target/release/smx PORT=4973 bash scripts/smoke_distributed.sh
set -u

BIN=${BIN:-target/release/smx}
PORT=${PORT:-4973}
ADDR=127.0.0.1:$PORT
OUT=${OUT:-$(mktemp -d)}

# `timeout` bounds the whole run so a worker that dies before connecting
# (serve would then block in accept() forever) fails the job fast instead
# of hanging until the CI-level timeout.
timeout "${SMOKE_TIMEOUT:-300}" "$BIN" serve --dataset tiny --workers 8 --methods diana+ \
  --sampling importance-diana --tau 2 --max-rounds 30 \
  --listen "$ADDR" --wire-workers 2 --out-dir "$OUT" --check-sim &
SERVE_PID=$!

"$BIN" worker --connect "$ADDR" &
W1=$!
"$BIN" worker --connect "$ADDR" &
W2=$!

rc=0
wait "$SERVE_PID" || rc=1
wait "$W1" || { echo "worker 1 failed" >&2; rc=1; }
wait "$W2" || { echo "worker 2 failed" >&2; rc=1; }

if [ "$rc" -ne 0 ]; then
  echo "distributed smoke FAILED" >&2
  exit 1
fi
echo "distributed smoke OK (serve + 2 workers, bitwise identical to run_sim)"
