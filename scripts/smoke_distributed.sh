#!/usr/bin/env bash
# Multi-process smoke test for the wire subsystem, nine legs:
#
#  1. steady state — one `smx serve` coordinator and two `smx worker`
#     processes on the synthetic tiny dataset (8 shards, 4 per worker
#     process) for a few rounds;
#  2. chaos — same topology plus a third (replacement) worker process;
#     worker 1 drops its connection right after receiving the round-5
#     downlink (`--die-after 5`, observably a SIGKILL at that instant),
#     the replacement rejoins via the Hello handshake and replays the
#     journal;
#  3. snapshot — chaos again with `--checkpoint-every 3`: the journal is
#     truncated at each committed worker-state snapshot, so the
#     replacement can only catch up via a snapshot restore — asserted by
#     its own `--expect-restore` exit code;
#  4. restart — durability: serve with `--run-dir` and a scripted
#     `--fault-plan kill-server@r10` dies mid-run with exit 137 (the
#     planned-kill code); the SAME worker processes ride out the gap on
#     `--max-retries`/`--retry-base-ms` backoff while a fresh serve,
#     pointed at the same run dir but without the fault plan, resumes
#     from the last committed snapshot + journal suffix and finishes
#     `--check-sim`-identical to the sim driver;
#  5. --driver distributed — the same protocol through the `Session`
#     front door from the plain `smx train` CLI (loopback transports, one
#     process), asserted bitwise against a `--driver sim` run by diffing
#     the residual-curve CSVs;
#  6. sa-quant — steady state again, but plain DCGD under the
#     smoothness-aware quantization compressor (`--compressor sa-quant`),
#     `--check-sim`-asserted bitwise against the sim driver so the
#     quantizer's RNG discipline and the Hello compressor fields are
#     exercised across real processes;
#  7. observability — serve again with `--metrics-addr` and `--run-dir`,
#     scrape `GET /metrics` and `GET /healthz` off the live server (the
#     endpoint shares the serve loop's poller), assert known series are
#     present, then walk the finished artifact store with `smx runs
#     list`/`show`;
#  8. relay — the hierarchical topology: serve with `--relay 1` talks to
#     ONE direct peer, an `smx relay` process that fans out to the two
#     real workers and merges their uplink frames verbatim into single
#     aggregate envelopes. A scripted fault plan (`kill@r6:relay`,
#     observably a SIGKILL at that instant) drops the relay on the
#     round-6 downlink; a replacement relay takes over the same address,
#     is caught up via snapshot restore + journal replay, and the
#     workers ride out the gap on their own backoff;
#  9. participation — `--participation tau=2` over three single-shard
#     worker processes with `--min-clients 2`: rounds start with two
#     workers, each round gathers only the sampled 2-shard cohort
#     (reweighted n/τ), and the third worker late-joins mid-run through
#     the snapshot/journal handshake without perturbing the trajectory.
#
# The serve legs pass `--check-sim`, which makes the server re-run the
# identical configuration through the in-process sim driver and exit
# nonzero unless the distributed iterates are bitwise identical — so the
# whole codec/transport/poller/runtime stack, including the recovery
# paths, is asserted by the server's exit code.
#
#   BIN=target/release/smx PORT=4973 bash scripts/smoke_distributed.sh
set -u

BIN=${BIN:-target/release/smx}
PORT=${PORT:-4973}
OUT=${OUT:-$(mktemp -d)}

run_leg() {
  local name=$1
  local addr=$2
  shift 2
  # `timeout` bounds the whole run so a worker that dies before connecting
  # (serve would then block in accept() forever) fails the job fast
  # instead of hanging until the CI-level timeout.
  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" serve --dataset tiny --workers 8 --methods diana+ \
    --sampling importance-diana --tau 2 --max-rounds 30 \
    --listen "$addr" --wire-workers 2 --out-dir "$OUT" --check-sim "$@" &
  local serve_pid=$!

  local rc=0
  local w_pids=()
  case $name in
    steady)
      "$BIN" worker --connect "$addr" &
      w_pids+=("$!")
      "$BIN" worker --connect "$addr" &
      w_pids+=("$!")
      ;;
    chaos)
      "$BIN" worker --connect "$addr" --die-after 5 &
      w_pids+=("$!")
      "$BIN" worker --connect "$addr" &
      w_pids+=("$!")
      # replacement: parks as a standby until worker 1's shards orphan,
      # then rejoins with a journal replay
      (sleep 1 && "$BIN" worker --connect "$addr") &
      w_pids+=("$!")
      ;;
    snapshot)
      # die after the round-6 snapshot committed (and truncated the
      # journal): the replacement cannot replay from round 0 anymore and
      # must be restored from the snapshot — --expect-restore makes the
      # worker itself fail otherwise
      "$BIN" worker --connect "$addr" --die-after 8 &
      w_pids+=("$!")
      "$BIN" worker --connect "$addr" &
      w_pids+=("$!")
      (sleep 1 && "$BIN" worker --connect "$addr" --expect-restore) &
      w_pids+=("$!")
      ;;
  esac

  wait "$serve_pid" || rc=1
  local i=1
  for pid in "${w_pids[@]}"; do
    wait "$pid" || { echo "[$name] worker $i failed" >&2; rc=1; }
    i=$((i + 1))
  done

  if [ "$rc" -ne 0 ]; then
    echo "distributed smoke FAILED ($name leg)" >&2
    exit 1
  fi
  echo "distributed smoke OK ($name leg: bitwise identical to run_sim)"
}

# sa-quant leg: the steady-state topology, but plain DCGD under the
# smoothness-aware quantization compressor. --check-sim again asserts the
# distributed iterates bitwise against the sim driver, which exercises
# the quantizer's value-independent RNG consumption and the Hello
# handshake's compressor/sa_levels/sa_weighting fields end to end.
sa_quant_leg() {
  local addr=$1
  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" serve --dataset tiny --workers 8 --methods dcgd \
    --sampling uniform --compressor sa-quant --sa-levels 4 --sa-weighting diag \
    --max-rounds 30 --listen "$addr" --wire-workers 2 --out-dir "$OUT" --check-sim &
  local serve_pid=$!
  "$BIN" worker --connect "$addr" &
  local w1=$!
  "$BIN" worker --connect "$addr" &
  local w2=$!

  local rc=0
  wait "$serve_pid" || rc=1
  local i=1
  for pid in "$w1" "$w2"; do
    wait "$pid" || { echo "[sa-quant] worker $i failed" >&2; rc=1; }
    i=$((i + 1))
  done
  if [ "$rc" -ne 0 ]; then
    echo "distributed smoke FAILED (sa-quant leg)" >&2
    exit 1
  fi
  echo "distributed smoke OK (sa-quant leg: bitwise identical to run_sim)"
}

# Leg 4 has a different shape (two serve invocations, one worker set), so
# it gets its own driver instead of a run_leg case.
restart_leg() {
  local addr=$1
  local run_dir="$OUT/runlog"
  rm -rf "$run_dir"
  local serve_args=(serve --dataset tiny --workers 8 --methods diana+
    --sampling importance-diana --tau 2 --max-rounds 30
    --listen "$addr" --wire-workers 2 --out-dir "$OUT"
    --worker-timeout 60 --checkpoint-every 3 --run-dir "$run_dir")

  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" "${serve_args[@]}" \
    --fault-plan kill-server@r10 &
  local serve_pid=$!
  "$BIN" worker --connect "$addr" --max-retries 20 --retry-base-ms 100 &
  local w1=$!
  "$BIN" worker --connect "$addr" --max-retries 20 --retry-base-ms 100 &
  local w2=$!

  local rc=0
  wait "$serve_pid" || rc=$?
  if [ "$rc" -ne 137 ]; then
    echo "distributed smoke FAILED (restart leg: expected the planned kill's exit 137, got $rc)" >&2
    exit 1
  fi
  if [ ! -f "$run_dir/base.bin" ]; then
    echo "distributed smoke FAILED (restart leg: kill left no committed run log)" >&2
    exit 1
  fi

  # Restart against the same run dir, without re-arming the kill. std's
  # TcpListener sets SO_REUSEADDR, so the rebind should succeed at once;
  # the retry only covers the instant between the old process's exit and
  # the kernel releasing its listener.
  local resumed=""
  for attempt in 1 2 3; do
    if timeout "${SMOKE_TIMEOUT:-300}" "$BIN" "${serve_args[@]}" --check-sim; then
      resumed=yes
      break
    fi
    echo "[restart] serve restart attempt $attempt failed; retrying" >&2
    sleep 1
  done
  if [ -z "$resumed" ]; then
    echo "distributed smoke FAILED (restart leg: resumed serve never matched the sim driver)" >&2
    exit 1
  fi

  local i=1
  for pid in "$w1" "$w2"; do
    wait "$pid" || { echo "distributed smoke FAILED (restart leg: worker $i)" >&2; exit 1; }
    i=$((i + 1))
  done
  echo "distributed smoke OK (restart leg: killed at round 10, resumed bitwise identical)"
}

# Leg 6: the serve topology again, with the Prometheus endpoint live and
# a run dir recording the stream. The endpoint is up from the moment
# serve binds (it answers while serve still waits in accept() for the
# workers), so the scrape loop below is guaranteed a window; it keeps
# retrying until the listener answers or the server exits.
metrics_leg() {
  local addr=$1
  local maddr=$2
  local run_dir="$OUT/metrics_runlog"
  rm -rf "$run_dir"
  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" serve --dataset tiny --workers 8 --methods diana+ \
    --sampling importance-diana --tau 2 --max-rounds 30 \
    --listen "$addr" --wire-workers 2 --out-dir "$OUT" --check-sim \
    --run-dir "$run_dir" --metrics-addr "$maddr" &
  local serve_pid=$!
  "$BIN" worker --connect "$addr" &
  local w1=$!
  "$BIN" worker --connect "$addr" &
  local w2=$!

  local health="" scraped=""
  for _ in {1..100}; do
    if health=$(curl -fsS --max-time 2 "http://$maddr/healthz" 2>/dev/null) &&
       scraped=$(curl -fsS --max-time 2 "http://$maddr/metrics" 2>/dev/null); then
      break
    fi
    kill -0 "$serve_pid" 2>/dev/null || break
    sleep 0.1
  done

  local rc=0
  wait "$serve_pid" || rc=1
  local i=1
  for pid in "$w1" "$w2"; do
    wait "$pid" || { echo "[metrics] worker $i failed" >&2; rc=1; }
    i=$((i + 1))
  done
  if [ "$rc" -ne 0 ]; then
    echo "distributed smoke FAILED (metrics leg: run)" >&2
    exit 1
  fi

  if [ "$health" != "ok" ]; then
    echo "distributed smoke FAILED (metrics leg: /healthz answered '$health', wanted 'ok')" >&2
    exit 1
  fi
  for series in smx_rounds_total smx_worker_connects_total smx_workers_live; do
    if ! grep -q "^$series " <<<"$scraped"; then
      echo "distributed smoke FAILED (metrics leg: /metrics is missing the $series series)" >&2
      echo "$scraped" >&2
      exit 1
    fi
  done

  # the finished run is now an artifact: the store must enumerate and
  # open it
  if ! "$BIN" runs list "$OUT" | grep -q "metrics_runlog"; then
    echo "distributed smoke FAILED (metrics leg: smx runs list does not see $run_dir)" >&2
    exit 1
  fi
  if ! "$BIN" runs show "$run_dir" >/dev/null; then
    echo "distributed smoke FAILED (metrics leg: smx runs show $run_dir)" >&2
    exit 1
  fi
  echo "distributed smoke OK (metrics leg: live scrape + runs list/show)"
}

# Leg 8: the relay topology (header comment 8). --check-sim asserts the
# whole story — merged uplink frames, the relay death, the replacement's
# snapshot-restore + journal-replay catch-up — bitwise against the sim
# driver via the server's exit code.
relay_leg() {
  local addr=$1
  local raddr=$2
  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" serve --dataset tiny --workers 8 --methods diana+ \
    --sampling importance-diana --tau 2 --max-rounds 30 \
    --listen "$addr" --wire-workers 2 --relay 1 --out-dir "$OUT" --check-sim \
    --worker-timeout 60 --checkpoint-every 4 &
  local serve_pid=$!

  # doomed relay: the scripted plan drops it on the round-6 downlink
  # without forwarding (its workers see EOF mid-round); the process
  # itself exits 0 — the kill is planned, not an error
  "$BIN" relay --connect "$addr" --listen "$raddr" --downstream 2 \
    --fault-plan kill@r6:relay &
  local doomed_pid=$!
  "$BIN" worker --connect "$raddr" --max-retries 20 --retry-base-ms 100 &
  local w1=$!
  "$BIN" worker --connect "$raddr" --max-retries 20 --retry-base-ms 100 &
  local w2=$!

  # replacement: waits for the doomed relay to vanish, then takes over
  # its listen address (the short retry covers the instant between the
  # old process exiting and the kernel releasing its listener)
  (
    while kill -0 "$doomed_pid" 2>/dev/null; do sleep 0.1; done
    for _ in 1 2 3; do
      "$BIN" relay --connect "$addr" --listen "$raddr" --downstream 2 && exit 0
      sleep 0.5
    done
    exit 1
  ) &
  local replacement_pid=$!

  local rc=0
  wait "$serve_pid" || rc=1
  wait "$doomed_pid" || { echo "[relay] doomed relay exited nonzero" >&2; rc=1; }
  wait "$replacement_pid" || { echo "[relay] replacement relay failed" >&2; rc=1; }
  local i=1
  for pid in "$w1" "$w2"; do
    wait "$pid" || { echo "[relay] worker $i failed" >&2; rc=1; }
    i=$((i + 1))
  done
  if [ "$rc" -ne 0 ]; then
    echo "distributed smoke FAILED (relay leg)" >&2
    exit 1
  fi
  echo "distributed smoke OK (relay leg: relay killed at round 6, replaced, bitwise identical to run_sim)"
}

# Leg 9: partial participation + first-class late join. Three shards on
# three worker processes with `--participation tau=2`: every round the
# server samples an unbiased 2-shard cohort (announced by the epoch
# frame), gathers only those uplinks, and reweights them by n/τ = 3/2.
# `--min-clients 2` lets rounds start with just the two on-time workers;
# the third connects a second late, is caught up through the snapshot/
# journal handshake, and its shard is gathered from its first cohort
# round onward. --check-sim asserts the whole story — cohort draws,
# reweighting, the late join — bitwise against the sim driver.
participation_leg() {
  local addr=$1
  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" serve --dataset tiny --workers 3 --methods diana+ \
    --sampling importance-diana --tau 2 --max-rounds 30 \
    --listen "$addr" --wire-workers 3 --out-dir "$OUT" --check-sim \
    --participation tau=2 --min-clients 2 --worker-timeout 60 --checkpoint-every 3 &
  local serve_pid=$!
  "$BIN" worker --connect "$addr" &
  local w1=$!
  "$BIN" worker --connect "$addr" &
  local w2=$!
  # the late joiner: rounds are already running when it arrives
  (sleep 1 && "$BIN" worker --connect "$addr") &
  local w3=$!

  local rc=0
  wait "$serve_pid" || rc=1
  local i=1
  for pid in "$w1" "$w2" "$w3"; do
    wait "$pid" || { echo "[participation] worker $i failed" >&2; rc=1; }
    i=$((i + 1))
  done
  if [ "$rc" -ne 0 ]; then
    echo "distributed smoke FAILED (participation leg)" >&2
    exit 1
  fi
  echo "distributed smoke OK (participation leg: tau=2 of 3 + late join, bitwise identical to run_sim)"
}

run_leg steady "127.0.0.1:$PORT"
run_leg chaos "127.0.0.1:$((PORT + 1))" --worker-timeout 60
run_leg snapshot "127.0.0.1:$((PORT + 2))" --worker-timeout 60 --checkpoint-every 3
restart_leg "127.0.0.1:$((PORT + 3))"
metrics_leg "127.0.0.1:$((PORT + 4))" "127.0.0.1:$((PORT + 5))"
sa_quant_leg "127.0.0.1:$((PORT + 6))"
relay_leg "127.0.0.1:$((PORT + 7))" "127.0.0.1:$((PORT + 8))"
participation_leg "127.0.0.1:$((PORT + 9))"

# --driver distributed: the Session front door from the plain train CLI.
# The wire protocol runs over loopback inside one process; its residual
# curve must be bitwise identical to the sim driver's (wall_secs, column
# 9, is the only legitimately differing column; bytes_down depends on the
# process fan-in, so compare through bytes_up, column 7).
for drv in sim distributed; do
  timeout "${SMOKE_TIMEOUT:-300}" "$BIN" train --dataset tiny --workers 8 --methods diana+ \
    --sampling importance-diana --tau 2 --max-rounds 30 --driver "$drv" \
    --wire-workers 2 --out-dir "$OUT/drv_$drv" \
    || { echo "train --driver $drv failed" >&2; exit 1; }
done
if ! diff <(cut -d, -f1-7 "$OUT/drv_sim/train_tiny.csv") \
          <(cut -d, -f1-7 "$OUT/drv_distributed/train_tiny.csv"); then
  echo "distributed smoke FAILED (--driver distributed diverged from --driver sim)" >&2
  exit 1
fi
echo "distributed smoke OK (--driver leg: train CSVs bitwise identical through column 7)"
