//! Bench: regenerate Figures 3 & 4 (effect of τ on DIANA+ convergence, in
//! rounds and in coordinates sent). The paper's claim: iteration count is
//! flat until τ crosses a threshold (smaller for importance sampling), so
//! total uplink communication *decreases* as τ shrinks.
//!
//!     cargo bench --bench fig34_tau_sweep

use smx::config::ExperimentConfig;
use smx::experiments::runner;
use smx::sampling::SamplingKind;
use smx::util::bench::bench_once;

fn main() -> anyhow::Result<()> {
    let ds = std::env::var("SMX_BENCH_DATASETS").unwrap_or_else(|_| "phishing".to_string());
    let ds = ds.split(',').next().unwrap().trim().to_string();
    let cfg = ExperimentConfig {
        dataset: ds.clone(),
        max_rounds: 60_000,
        target_residual: 1e-9,
        record_every: 100,
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let (prep, _) = bench_once(&format!("[{ds}] prepare + x*"), || {
        runner::prepare(&cfg).unwrap()
    });
    let d = prep.sm.dim as f64;
    let eps = 1e-8;

    println!("\n== Figures 3+4 bench: τ-sweep on {ds} (d = {}) ==", prep.sm.dim);
    println!("tau      sampling     rounds→{eps:.0e}   coords→{eps:.0e}     wall");
    for tau in [1.0, 2.0, 4.0, 8.0, (d / 4.0).max(1.0).floor(), d] {
        for (sname, skind) in [
            ("importance", SamplingKind::ImportanceDiana),
            ("uniform", SamplingKind::Uniform),
        ] {
            let (r, secs) = bench_once(&format!("[{ds}] tau={tau} {sname}"), || {
                runner::run_one(&prep, &cfg, "diana+", skind, tau).unwrap()
            });
            match (r.rounds_to(eps), r.coords_to(eps)) {
                (Some(it), Some(c)) =>

                    println!("{tau:<8} {sname:<12} {it:>10}   {c:>14}   {secs:>7.2}s"),
                _ => println!(
                    "{tau:<8} {sname:<12} not reached ({:.2e})",
                    r.final_residual()
                ),
            }
        }
    }
    Ok(())
}
