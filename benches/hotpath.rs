//! Hot-path micro-benches (§Perf): the per-round cost centers of the
//! three-layer stack, native and PJRT.
//!
//!   worker:  grad (native CSR)  |  grad (PJRT artifact)  |  whiten L^{†1/2}v
//!   server:  sparse decompress L^{1/2}Δ  |  full server apply
//!   sampling: Bernoulli draw + water-filling solve
//!
//!     cargo bench --bench hotpath

use smx::compress::{MatrixAware, SparseMsg};
use smx::data::synth;
use smx::objective::smoothness::build_local;
use smx::runtime::artifact::Manifest;
use smx::runtime::native::NativeEngine;
use smx::runtime::pjrt::PjrtEngine;
use smx::runtime::GradEngine;
use smx::sampling::{solvers, IndependentSampling};
use smx::util::bench::{bench, black_box};
use smx::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);

    // a8a-scale shard: m=2837, d=123 (the e2e workload)
    let spec = synth::spec_by_name("a8a").unwrap();
    let ds = synth::generate(spec, 1);
    let (_, shards) = ds.prepare(spec.n, 1);
    let shard = &shards[0];
    let (m, d) = (shard.num_points(), shard.dim());
    println!("== hot path micro-benches (a8a shard: m={m}, d={d}) ==\n");

    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut g = vec![0.0; d];

    // L1/L2 gradient: native vs PJRT
    let mut native = NativeEngine::from_shard(shard, 1e-3);
    bench("grad native (CSR fused)", 300, || {
        native.grad_into(black_box(&x), &mut g);
    });
    match Manifest::load(&smx::runtime::artifact::default_dir()) {
        Ok(manifest) => {
            let mut pjrt = PjrtEngine::from_shard(&manifest, shard, 1e-3)?;
            bench("grad pjrt (AOT JAX/Pallas artifact)", 300, || {
                pjrt.grad_into(black_box(&x), &mut g);
            });
        }
        Err(e) => println!("(skipping pjrt: {e})"),
    }

    // smoothness root application (worker whiten + server decompress)
    let loc = build_local(&shard.a, 1e-3);
    let mut w = vec![0.0; d];
    bench("whiten L^(-1/2) v (dense root, d=123)", 200, || {
        loc.root.apply_pow_into(-0.5, black_box(&x), &mut w);
    });
    // §Perf reference: the pre-optimization column-strided V access,
    // re-materialized here so before/after stays measurable
    if let smx::linalg::PsdRoot::Dense { eig, dim, .. } = &loc.root {
        let n = *dim;
        let mut coeff = vec![0.0; n];
        bench("whiten strided (pre-opt reference)", 200, || {
            let xb = black_box(&x);
            for c in 0..n {
                let mut s = 0.0;
                for r in 0..n {
                    s += eig.v[(r, c)] * xb[r];
                }
                coeff[c] = s * eig.w[c].max(0.0).powf(-0.5);
            }
            for r in 0..n {
                let mut s = 0.0;
                for c in 0..n {
                    s += eig.v[(r, c)] * coeff[c];
                }
                w[r] = s;
            }
        });
    }

    let sampling = IndependentSampling::uniform(d, 4.0);
    let mut ma = MatrixAware::new(sampling.clone());
    let mut msg = SparseMsg::new();
    bench("worker compress (whiten + sketch, tau=4)", 200, || {
        ma.compress(&loc.root, black_box(&x), &mut rng, &mut msg);
    });
    bench("server decompress L^(1/2) Δ (sparse, tau=4)", 200, || {
        loc.root
            .apply_pow_sparse_into(0.5, black_box(&msg.idx), &msg.val, &mut g);
    });

    // duke-scale low-rank root (d=7129, k=11)
    let duke = synth::spec_by_name("duke").unwrap();
    let dds = synth::generate(duke, 1);
    let (_, dshards) = dds.prepare(duke.n, 1);
    let dloc = build_local(&dshards[0].a, 1e-3);
    let dx: Vec<f64> = (0..dshards[0].dim()).map(|_| rng.normal()).collect();
    let mut dw = vec![0.0; dshards[0].dim()];
    bench("whiten low-rank root (duke d=7129 k~11)", 200, || {
        dloc.root.apply_pow_into(-0.5, black_box(&dx), &mut dw);
    });

    // sampling machinery
    let mut buf = Vec::new();
    bench("bernoulli sample d=123 tau=4", 100, || {
        sampling.sample_into(&mut rng, &mut buf);
    });
    bench("water-filling solve (eq.19, d=123)", 100, || {
        black_box(solvers::probs_diana_plus(&loc.diag, 4.0, 1e-3, 8));
    });

    Ok(())
}
