//! Hot-path micro-benches (§Perf): the per-round cost centers of the
//! three-layer stack, native and PJRT, plus end-to-end rounds.
//!
//!   kernels: every SIMD dispatch arm (scalar blocked / avx2 / avx512
//!            where available) of dot/axpy/CSR matvec+tmatvec, vs the
//!            retained naive references
//!   worker:  grad (native CSR)  |  grad (PJRT artifact)  |  whiten L^{†1/2}v
//!   server:  sparse decompress L^{1/2}Δ  |  full server apply
//!   sampling: Bernoulli draw + water-filling solve
//!   wire:    codec encode/decode (f64/f32/q8 payloads, delta-varint idx)
//!   rounds:  dcgd+/diana+ end-to-end, buffer-reusing vs pre-opt
//!            allocating, dcgd under the sa-quant compressor, and
//!            distributed(loopback) across worker threads
//!
//!     cargo bench --bench hotpath
//!
//! Every row is also appended to `BENCH_hotpath.json` (median/min/p95 ns)
//! so later PRs can diff the perf trajectory — see
//! `scripts/bench_trajectory.sh`.

#![allow(clippy::needless_range_loop)]

use smx::compress::{topk_compress, MatrixAware, SparseMsg};
use smx::data::synth;
use smx::linalg::simd::{self, Level};
use smx::linalg::sparse::Csr;
use smx::methods::{build, sync_round, Method, MethodSpec, RoundBuffers, Uplink};
use smx::objective::smoothness::build_local;
use smx::objective::Smoothness;
use smx::runtime::artifact::Manifest;
use smx::runtime::native::NativeEngine;
use smx::runtime::pjrt::PjrtEngine;
use smx::runtime::GradEngine;
use smx::sampling::{solvers, IndependentSampling, SamplingKind};
use smx::util::bench::{bench, black_box, BenchResult};
use smx::util::json::Json;
use smx::util::rng::Rng;
use smx::wire::codec as wcodec;
use smx::wire::runtime::{
    server_round, worker_loop, HostedShards, ServerRoundState, ShardRunner, WorkerHost,
    WorkerState,
};
use smx::wire::{loopback_pair, Payload};

// ---- pre-opt reference kernels (scalar loops, what the blocked versions
// replaced; kept here so before/after stays measurable) -----------------

fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

fn naive_axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

fn naive_csr_matvec_into(a: &Csr, x: &[f64], out: &mut [f64]) {
    for r in 0..a.rows {
        let (idx, val) = a.row_entries(r);
        let mut s = 0.0;
        for k in 0..idx.len() {
            s += val[k] * x[idx[k] as usize];
        }
        out[r] = s;
    }
}

fn naive_csr_tmatvec_into(a: &Csr, y: &[f64], out: &mut [f64]) {
    out.fill(0.0);
    for r in 0..a.rows {
        let yr = y[r];
        if yr == 0.0 {
            continue;
        }
        let (idx, val) = a.row_entries(r);
        for k in 0..idx.len() {
            out[idx[k] as usize] += yr * val[k];
        }
    }
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let mut rows: Vec<BenchResult> = Vec::new();

    // a8a-scale shard: m=2837, d=123 (the e2e workload)
    let spec = synth::spec_by_name("a8a").unwrap();
    let ds = synth::generate(spec, 1);
    let (_, shards) = ds.prepare(spec.n, 1);
    let shard = &shards[0];
    let (m, d) = (shard.num_points(), shard.dim());
    println!("== hot path micro-benches (a8a shard: m={m}, d={d}) ==\n");

    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut g = vec![0.0; d];

    // L0 kernels: every dispatch arm (scalar blocked, avx2, avx512 where
    // the CPU has it) vs the naive pre-opt references, on the a8a shapes.
    // The arm rows share one name scheme — "<kernel> <arm>" — so
    // BENCH_hotpath.json diffs show the scalar-vs-SIMD margin per kernel.
    let arms = Level::available();
    println!("simd arms: {:?} (active: {:?})\n", arms, simd::active());
    {
        let a: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        for &lvl in &arms {
            rows.push(bench(&format!("dot {} (n=4096)", lvl.name()), 100, || {
                black_box(simd::dot_at(lvl, black_box(&a), black_box(&b)));
            }));
        }
        rows.push(bench("dot naive (pre-opt reference)", 100, || {
            black_box(naive_dot(black_box(&a), black_box(&b)));
        }));
        let mut y = vec![0.0; 4096];
        for &lvl in &arms {
            rows.push(bench(&format!("axpy {} (n=4096)", lvl.name()), 100, || {
                simd::axpy_at(lvl, 1.0000001, black_box(&a), &mut y);
            }));
        }
        rows.push(bench("axpy naive (pre-opt reference)", 100, || {
            naive_axpy(1.0000001, black_box(&a), &mut y);
        }));

        let mut gm = vec![0.0; m];
        for &lvl in &arms {
            rows.push(bench(
                &format!("csr matvec {} (a8a grad half)", lvl.name()),
                200,
                || {
                    simd::csr_matvec_into_at(
                        lvl,
                        &shard.a.indptr,
                        &shard.a.indices,
                        &shard.a.values,
                        black_box(&x),
                        &mut gm,
                    );
                },
            ));
        }
        rows.push(bench("csr matvec naive (pre-opt reference)", 200, || {
            naive_csr_matvec_into(&shard.a, black_box(&x), &mut gm);
        }));
        let ym: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        for &lvl in &arms {
            rows.push(bench(
                &format!("csr tmatvec {} (a8a grad half)", lvl.name()),
                200,
                || {
                    simd::csr_tmatvec_into_at(
                        lvl,
                        &shard.a.indptr,
                        &shard.a.indices,
                        &shard.a.values,
                        black_box(&ym),
                        &mut g,
                    );
                },
            ));
        }
        rows.push(bench("csr tmatvec naive (pre-opt reference)", 200, || {
            naive_csr_tmatvec_into(&shard.a, black_box(&ym), &mut g);
        }));
    }

    // L1/L2 gradient: native vs PJRT
    let mut native = NativeEngine::from_shard(shard, 1e-3);
    rows.push(bench("grad native (CSR fused)", 300, || {
        native.grad_into(black_box(&x), &mut g);
    }));
    match Manifest::load(&smx::runtime::artifact::default_dir()) {
        Ok(manifest) => match PjrtEngine::from_shard(&manifest, shard, 1e-3) {
            Ok(mut pjrt) => {
                rows.push(bench("grad pjrt (AOT JAX/Pallas artifact)", 300, || {
                    pjrt.grad_into(black_box(&x), &mut g);
                }));
            }
            Err(e) => println!("(skipping pjrt engine: {e})"),
        },
        Err(e) => println!("(skipping pjrt: {e})"),
    }

    // smoothness root application (worker whiten + server decompress)
    let loc = build_local(&shard.a, 1e-3);
    let mut w = vec![0.0; d];
    let mut coeff = Vec::new();
    rows.push(bench("whiten L^(-1/2) v (dense root, d=123)", 200, || {
        loc.root
            .apply_pow_into_with(-0.5, black_box(&x), &mut w, &mut coeff);
    }));
    // §Perf reference: the pre-optimization column-strided V access,
    // re-materialized here so before/after stays measurable
    if let smx::linalg::PsdRoot::Dense { eig, dim, .. } = &loc.root {
        let n = *dim;
        let mut strided_coeff = vec![0.0; n];
        rows.push(bench("whiten strided (pre-opt reference)", 200, || {
            let xb = black_box(&x);
            for c in 0..n {
                let mut s = 0.0;
                for r in 0..n {
                    s += eig.v[(r, c)] * xb[r];
                }
                strided_coeff[c] = s * eig.w[c].max(0.0).powf(-0.5);
            }
            for r in 0..n {
                let mut s = 0.0;
                for c in 0..n {
                    s += eig.v[(r, c)] * strided_coeff[c];
                }
                w[r] = s;
            }
        }));
    }

    let sampling = IndependentSampling::uniform(d, 4.0);
    let mut ma = MatrixAware::new(sampling.clone());
    let mut msg = SparseMsg::new();
    rows.push(bench("worker compress (whiten + sketch, tau=4)", 200, || {
        ma.compress(&loc.root, black_box(&x), &mut rng, &mut msg);
    }));
    rows.push(bench("server decompress L^(1/2) Δ (sparse, tau=4)", 200, || {
        loc.root
            .apply_pow_sparse_into_with(0.5, black_box(&msg.idx), &msg.val, &mut g, &mut coeff);
    }));

    // duke-scale low-rank root (d=7129, k=11): the fused single-matrix
    // apply (what apply_pow_into_with now routes to) vs the pre-fusion
    // two-matrix reference (QT cached row-major + Q, both streamed cold)
    let duke = synth::spec_by_name("duke").unwrap();
    let dds = synth::generate(duke, 1);
    let (_, dshards) = dds.prepare(duke.n, 1);
    let dloc = build_local(&dshards[0].a, 1e-3);
    let dx: Vec<f64> = (0..dshards[0].dim()).map(|_| rng.normal()).collect();
    let mut dw = vec![0.0; dshards[0].dim()];
    rows.push(bench("whiten low-rank fused (duke d=7129 k~11)", 200, || {
        dloc.root
            .apply_pow_fused_into(-0.5, black_box(&dx), &mut dw, &mut coeff);
    }));
    if let smx::linalg::PsdRoot::LowRankRidge { q, lam, mu, dim } = &dloc.root {
        let qt = q.transpose();
        let k = lam.len();
        let mut coeffv = vec![0.0; k];
        let p = -0.5;
        let mus = if *mu <= 0.0 { 0.0 } else { mu.powf(p) };
        rows.push(bench("whiten low-rank unfused (pre-opt reference)", 200, || {
            let xb = black_box(&dx);
            for c in 0..k {
                coeffv[c] = smx::linalg::vector::dot(qt.row(c), xb)
                    * ((lam[c] + *mu).powf(p) - mus);
            }
            for r in 0..*dim {
                dw[r] = mus * xb[r] + smx::linalg::vector::dot(q.row(r), &coeffv);
            }
        }));
    }

    // wire codec: top-k uplink on the duke shape (d=7129 — where the
    // delta-varint index coding beats the modeled ⌈log₂ d⌉ account)
    {
        let mut up = Uplink::default();
        topk_compress(&dx, 128, &mut up.delta);
        let mut enc = Vec::new();
        for p in [Payload::F64, Payload::F32, Payload::Q8] {
            rows.push(bench(
                &format!("codec encode uplink top-128 d=7129 ({})", p.name()),
                300,
                || {
                    enc.clear();
                    wcodec::put_uplink(&mut enc, black_box(&up), 0, p).unwrap();
                    black_box(enc.len());
                },
            ));
        }
        enc.clear();
        wcodec::put_uplink(&mut enc, &up, 0, Payload::F64).unwrap();
        let mut dec = Uplink::default();
        rows.push(bench("codec decode uplink top-128 d=7129 (f64)", 300, || {
            black_box(wcodec::get_uplink(black_box(&enc), 7129, &mut dec).unwrap());
        }));

        // relay merge: combine 8 sibling top-128 uplinks into one
        // aggregate envelope — the per-round hot path of an `smx relay`
        // tier. Gated row (see scripts/bench_gate.py): the merge is pure
        // header parsing + verbatim copies and must stay that way.
        let sibs: Vec<Vec<u8>> = (0..8)
            .map(|shard| {
                let mut f = Vec::new();
                wcodec::put_uplink(&mut f, &up, shard, Payload::F64).unwrap();
                f
            })
            .collect();
        let refs: Vec<&[u8]> = sibs.iter().map(|f| f.as_slice()).collect();
        let mut merged = Vec::new();
        rows.push(bench("relay merge 8x top-128 d=7129 (f64)", 300, || {
            wcodec::merge_uplinks(&mut merged, black_box(&refs)).unwrap();
            black_box(merged.len());
        }));

        let down = smx::methods::Downlink::Dense {
            x: x.clone(),
            w: None,
        };
        let mut dbuf = Vec::new();
        rows.push(bench("codec encode dense downlink d=123 (f64)", 300, || {
            dbuf.clear();
            wcodec::put_downlink(&mut dbuf, black_box(&down), Payload::F64).unwrap();
        }));
        let mut ddec = smx::methods::Downlink::Init { x: Vec::new() };
        rows.push(bench("codec decode dense downlink d=123 (f64)", 300, || {
            wcodec::get_downlink(black_box(&dbuf), 123, &mut ddec).unwrap();
        }));
    }

    // sampling machinery
    let mut buf = Vec::new();
    rows.push(bench("bernoulli sample d=123 tau=4", 100, || {
        sampling.sample_into(&mut rng, &mut buf);
    }));
    rows.push(bench("water-filling solve (eq.19, d=123)", 100, || {
        black_box(solvers::probs_diana_plus(&loc.diag, 4.0, 1e-3, 8));
    }));

    // L3 end-to-end rounds: buffer-reusing protocol vs the pre-opt
    // allocating loop (fresh Downlink + Vec<Uplink> per round)
    println!();
    let sm = Smoothness::build(&shards, 1e-3);
    for name in ["dcgd+", "diana+"] {
        let mspec = MethodSpec::new(name, 4.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);

        let mut method = build(&mspec, &sm)?;
        let mut engines: Vec<Box<dyn GradEngine>> = shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
            .collect();
        let base = Rng::new(1);
        let mut server_rng = base.derive(u64::MAX);
        let mut worker_rngs: Vec<Rng> = (0..shards.len()).map(|i| base.derive(i as u64)).collect();
        let mut bufs = RoundBuffers::new(shards.len());
        rows.push(bench(
            &format!("round e2e {name} (buffer-reusing, n=8)"),
            400,
            || {
                sync_round(
                    &mut method,
                    &mut engines,
                    &mut server_rng,
                    &mut worker_rngs,
                    &mut bufs,
                );
            },
        ));

        let mut method2 = build(&mspec, &sm)?;
        let mut engines2: Vec<Box<dyn GradEngine>> = shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
            .collect();
        let mut server_rng2 = base.derive(u64::MAX);
        let mut worker_rngs2: Vec<Rng> = (0..shards.len()).map(|i| base.derive(i as u64)).collect();
        rows.push(bench(
            &format!("round e2e {name} (pre-opt allocating)"),
            400,
            || {
                let down = method2.server.downlink();
                let ups: Vec<Uplink> = method2
                    .workers
                    .iter_mut()
                    .zip(engines2.iter_mut())
                    .zip(worker_rngs2.iter_mut())
                    .map(|((wk, e), r)| wk.round(&down, e.as_mut(), r))
                    .collect();
                method2.server.apply(&ups, &mut server_rng2);
            },
        ));
    }

    // smoothness-aware quantization round: plain dcgd with the sa-quant
    // uplink compressor (diag weighting, s=4 levels). The margin against
    // "round e2e dcgd+ (buffer-reusing, n=8)" is the per-round price of
    // quantize+dequantize relative to the matrix-aware sketch.
    {
        let mut mspec =
            MethodSpec::new("dcgd", 4.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        mspec.compressor = smx::compress::CompressorKind::SaQuant;
        mspec.sa_levels = 4;
        let mut method = build(&mspec, &sm)?;
        let mut engines: Vec<Box<dyn GradEngine>> = shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
            .collect();
        let base = Rng::new(1);
        let mut server_rng = base.derive(u64::MAX);
        let mut worker_rngs: Vec<Rng> = (0..shards.len()).map(|i| base.derive(i as u64)).collect();
        let mut bufs = RoundBuffers::new(shards.len());
        rows.push(bench("round e2e dcgd sa-quant (buffer-reusing, n=8)", 400, || {
            sync_round(
                &mut method,
                &mut engines,
                &mut server_rng,
                &mut worker_rngs,
                &mut bufs,
            );
        }));
    }

    // partial-participation round: the buffer-reusing diana+ round under
    // `--participation tau=n/2` — per round: a cohort draw (partial
    // Fisher–Yates over the membership RNG stream), sampled-out uplink
    // clears, the n/τ unbiasedness reweight, then the server apply. The
    // margin against "round e2e diana+ (buffer-reusing, n=8)" is the
    // sampler's bookkeeping minus the skipped worker computes.
    {
        use smx::coordinator::membership::{self, Participation};
        let mspec = MethodSpec::new("diana+", 4.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut method = build(&mspec, &sm)?;
        let mut engines: Vec<Box<dyn GradEngine>> = shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
            .collect();
        let base = Rng::new(1);
        let mut server_rng = base.derive(u64::MAX);
        let mut worker_rngs: Vec<Rng> = (0..shards.len()).map(|i| base.derive(i as u64)).collect();
        let mut bufs = RoundBuffers::new(shards.len());
        let mut participation = Participation::new(1, shards.len(), shards.len() / 2)?;
        let weight = participation.weight();
        let mut round = 0u64;
        rows.push(bench("round e2e diana+ (tau=n/2, n=8)", 400, || {
            round += 1;
            let RoundBuffers { down, ups } = &mut bufs;
            method.server.downlink_into(down);
            let mask = participation.draw(round);
            for (i, up) in ups.iter_mut().enumerate() {
                if mask[i] {
                    method.workers[i].round_into(down, engines[i].as_mut(), &mut worker_rngs[i], up);
                    membership::reweight_uplink(up, weight);
                } else {
                    membership::clear_uplink(up);
                }
            }
            method.server.apply(ups, &mut server_rng);
        }));
    }

    // observability cost: the identical buffer-reusing diana+ round with
    // the full per-round metrics hot path attached — rounds counter,
    // duration histogram, and the seqlock round-block write the
    // `/metrics` endpoint reads. The margin against "round e2e diana+
    // (buffer-reusing, n=8)" is the per-round price of `--metrics-addr`.
    {
        let mspec = MethodSpec::new("diana+", 4.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut method = build(&mspec, &sm)?;
        let mut engines: Vec<Box<dyn GradEngine>> = shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
            .collect();
        let base = Rng::new(1);
        let mut server_rng = base.derive(u64::MAX);
        let mut worker_rngs: Vec<Rng> = (0..shards.len()).map(|i| base.derive(i as u64)).collect();
        let mut bufs = RoundBuffers::new(shards.len());
        let registry = smx::obs::Registry::new(shards.len());
        let mut rec = smx::coordinator::RoundRecord {
            round: 0,
            residual: 1.0,
            coords_up: 0,
            bits_up: 0,
            coords_down: 0,
            bytes_up: 0,
            bytes_down: 0,
            wall_secs: 0.0,
            compute_secs: 0.0,
            encode_secs: 0.0,
            wire_secs: 0.0,
        };
        rows.push(bench("round e2e diana+ (metrics on, n=8)", 400, || {
            let t = std::time::Instant::now();
            sync_round(
                &mut method,
                &mut engines,
                &mut server_rng,
                &mut worker_rngs,
                &mut bufs,
            );
            rec.round += 1;
            rec.bytes_up += 4096;
            registry.rounds.inc();
            registry.round_duration.observe(t.elapsed().as_secs_f64());
            registry.round.write(&rec);
        }));
    }

    // distributed round over loopback transports: the same diana+ round,
    // but messages travel the wire codec between the server and 2 worker
    // threads (4 shards each)
    {
        let mspec = MethodSpec::new("diana+", 4.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let method = build(&mspec, &sm)?;
        let Method {
            mut server,
            workers,
            name: _,
        } = method;
        let n = workers.len();
        let procs = 2usize.min(n);
        let base = Rng::new(1);
        let mut server_rng = base.derive(u64::MAX);
        let mut groups: Vec<HostedShards> = (0..procs).map(|_| Vec::new()).collect();
        for (i, w) in workers.into_iter().enumerate() {
            groups[i % procs].push((i, w));
        }
        let mut hosts: Vec<WorkerHost> = Vec::with_capacity(procs);
        let mut ends = Vec::with_capacity(procs);
        for g in &groups {
            let (a, b) = loopback_pair();
            hosts.push(WorkerHost {
                transport: Box::new(a),
                shards: g.iter().map(|(i, _)| *i).collect(),
            });
            ends.push(b);
        }
        let shards_ref = &shards;
        std::thread::scope(|scope| {
            for (mut end, group) in ends.into_iter().zip(groups.into_iter()) {
                let base = base.clone();
                scope.spawn(move || {
                    let runners: Vec<ShardRunner> = group
                        .into_iter()
                        .map(|(i, w)| {
                            ShardRunner::new(
                                i,
                                w,
                                Box::new(NativeEngine::from_shard(&shards_ref[i], 1e-3))
                                    as Box<dyn GradEngine>,
                                base.derive(i as u64),
                            )
                        })
                        .collect();
                    let mut state = WorkerState::for_loopback(runners, Payload::F64, 1);
                    let _ = worker_loop(&mut state, &mut end);
                });
            }
            let mut st = ServerRoundState::new(n);
            rows.push(bench(
                "round e2e diana+ distributed(loopback, 2 procs)",
                400,
                || {
                    server_round(
                        server.as_mut(),
                        &mut hosts,
                        &mut st,
                        &mut server_rng,
                        Payload::F64,
                        64,
                    )
                    .unwrap();
                },
            ));
            for h in hosts.iter_mut() {
                let _ = h.transport.send(&[wcodec::TAG_STOP]);
            }
        });
    }

    // the Session front door end-to-end: method build + engine
    // construction + a 10-round sim run per iteration — measures the
    // builder/observer seam's overhead on top of the raw round loop
    {
        use smx::coordinator::{RunConfig, Session};
        let mspec = MethodSpec::new("diana+", 4.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let x_star = vec![0.0; sm.dim];
        let run_cfg = RunConfig {
            max_rounds: 10,
            ..Default::default()
        };
        rows.push(bench("session e2e diana+ (sim, 10 rounds, n=8)", 40, || {
            let engines: Vec<Box<dyn GradEngine>> = shards
                .iter()
                .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
                .collect();
            let r = Session::new(mspec.clone())
                .smoothness(&sm)
                .x_star(&x_star)
                .engines(engines)
                .run_config(run_cfg.clone())
                .run()
                .unwrap();
            black_box(r.rounds_run);
        }));
    }

    // channel substrate: the threaded driver's SPSC ring (preallocated
    // slots, zero allocs per message) vs the mpsc channel it replaced
    // (allocates internal blocks per send) — one message ping-ponged
    // between two threads per iteration
    {
        use std::sync::mpsc;
        let (ping_tx, ping_rx) = smx::util::ring::ring::<Uplink>(2);
        let (pong_tx, pong_rx) = smx::util::ring::ring::<Uplink>(2);
        let echo = std::thread::spawn(move || {
            while let Ok(v) = ping_rx.recv() {
                if pong_tx.send(v).is_err() {
                    break;
                }
            }
        });
        let mut slot = Some(Uplink::default());
        rows.push(bench("channel ping-pong spsc ring (Uplink)", 150, || {
            ping_tx.send(slot.take().unwrap()).unwrap();
            slot = Some(pong_rx.recv().unwrap());
        }));
        drop(ping_tx);
        echo.join().unwrap();

        let (ping_tx, ping_rx) = mpsc::channel::<Uplink>();
        let (pong_tx, pong_rx) = mpsc::channel::<Uplink>();
        let echo = std::thread::spawn(move || {
            while let Ok(v) = ping_rx.recv() {
                if pong_tx.send(v).is_err() {
                    break;
                }
            }
        });
        let mut slot = Some(Uplink::default());
        rows.push(bench(
            "channel ping-pong mpsc (pre-opt reference)",
            150,
            || {
                ping_tx.send(slot.take().unwrap()).unwrap();
                slot = Some(pong_rx.recv().unwrap());
            },
        ));
        drop(ping_tx);
        echo.join().unwrap();
    }

    // perf trajectory artifact
    let entries: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("iters", Json::Num(r.iters as f64)),
                ("min_ns", Json::Num(r.min_ns)),
                ("median_ns", Json::Num(r.median_ns)),
                ("p95_ns", Json::Num(r.p95_ns)),
                ("mean_ns", Json::Num(r.mean_ns)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("unit", Json::Str("ns".into())),
        ("results", Json::Arr(entries)),
    ]);
    std::fs::write("BENCH_hotpath.json", doc.to_string_pretty())?;
    println!("\nwrote BENCH_hotpath.json ({} rows)", rows.len());

    Ok(())
}
