//! Bench: regenerate Figure 1 (DIANA+ importance vs DIANA+ uniform vs
//! DIANA uniform, τ = 1) and report rounds/coords-to-target plus wall
//! time per method — the end-to-end series the paper plots.
//!
//!     cargo bench --bench fig1_variance_reduction
//!     SMX_BENCH_DATASETS=a1a,mushrooms cargo bench --bench fig1_variance_reduction

use smx::config::ExperimentConfig;
use smx::experiments::runner;
use smx::sampling::SamplingKind;
use smx::util::bench::bench_once;

fn main() -> anyhow::Result<()> {
    let datasets = std::env::var("SMX_BENCH_DATASETS")
        .unwrap_or_else(|_| "phishing,mushrooms".to_string());
    println!("== Figure 1 bench: variance reduction + matrix-aware sparsification (τ=1) ==\n");
    for ds in datasets.split(',') {
        let cfg = ExperimentConfig {
            dataset: ds.trim().to_string(),
            tau: 1.0,
            max_rounds: 40_000,
            target_residual: 1e-10,
            record_every: 50,
            out_dir: "results/bench".into(),
            ..Default::default()
        };
        let (prep, _) = bench_once(&format!("[{ds}] prepare + x*"), || {
            runner::prepare(&cfg).unwrap()
        });
        println!(
            "[{ds}] d={} n={} | variant                      rounds→1e-8      coords→1e-8     wall",
            prep.sm.dim,
            prep.sm.n()
        );
        for (label, method, sampling) in [
            ("diana+-importance", "diana+", SamplingKind::ImportanceDiana),
            ("diana+-uniform", "diana+", SamplingKind::Uniform),
            ("diana-uniform", "diana", SamplingKind::Uniform),
        ] {
            let (r, secs) = bench_once(&format!("[{ds}] {label}"), || {
                runner::run_one(&prep, &cfg, method, sampling, 1.0).unwrap()
            });
            let eps = 1e-8;
            match (r.rounds_to(eps), r.coords_to(eps)) {
                (Some(it), Some(c)) => println!(
                    "    {label:<28} {it:>10}   {c:>14}   {secs:>8.2}s"
                ),
                _ => println!(
                    "    {label:<28} not reached ({:.2e} after {})",
                    r.final_residual(),
                    r.rounds_run
                ),
            }
        }
        println!();
    }
    Ok(())
}
