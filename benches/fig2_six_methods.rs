//! Bench: regenerate Figure 2 (DCGD/DIANA/ADIANA vs the "+" redesigns,
//! uniform τ = 1, started near x*). Reports rounds-to-target per method —
//! the paper's qualitative claims are: (i) every + beats its baseline,
//! (ii) acceleration wins, (iii) variance reduction kills the DCGD
//! plateau.
//!
//!     cargo bench --bench fig2_six_methods

use smx::config::ExperimentConfig;
use smx::experiments::runner;
use smx::sampling::SamplingKind;
use smx::util::bench::bench_once;

fn main() -> anyhow::Result<()> {
    let datasets =
        std::env::var("SMX_BENCH_DATASETS").unwrap_or_else(|_| "phishing".to_string());
    println!("== Figure 2 bench: originals vs matrix-aware redesigns (uniform τ=1) ==\n");
    for ds in datasets.split(',') {
        let cfg = ExperimentConfig {
            dataset: ds.trim().to_string(),
            tau: 1.0,
            max_rounds: 40_000,
            target_residual: 1e-10,
            record_every: 50,
            start_near_opt: true,
            out_dir: "results/bench".into(),
            ..Default::default()
        };
        let (prep, _) = bench_once(&format!("[{ds}] prepare + x*"), || {
            runner::prepare(&cfg).unwrap()
        });
        let eps = 1e-8;
        let mut rounds = std::collections::BTreeMap::new();
        for method in ["dcgd", "dcgd+", "diana", "diana+", "adiana", "adiana+"] {
            let (r, secs) = bench_once(&format!("[{ds}] {method}"), || {
                runner::run_one(&prep, &cfg, method, SamplingKind::Uniform, 1.0).unwrap()
            });
            let reached = r.rounds_to(eps);
            rounds.insert(method.to_string(), reached);
            match reached {
                Some(it) => println!("    {method:<10} {it:>10} rounds   {secs:>8.2}s"),
                None => println!(
                    "    {method:<10} plateau at {:.2e} ({} rounds, {secs:.2}s)",
                    r.final_residual(),
                    r.rounds_run
                ),
            }
        }
        for (plus, base) in [("dcgd+", "dcgd"), ("diana+", "diana"), ("adiana+", "adiana")] {
            match (rounds[plus], rounds[base]) {
                (Some(p), Some(b)) => println!(
                    "    claim: {plus} beats {base}: {}  ({b} vs {p} rounds, {:.2}x)",
                    p <= b,
                    b as f64 / p as f64
                ),
                (Some(_), None) => println!("    claim: {plus} beats {base}: true (baseline plateaued)"),
                _ => println!("    claim: {plus} vs {base}: both plateaued (DCGD neighborhood)"),
            }
        }
        println!();
    }
    Ok(())
}
