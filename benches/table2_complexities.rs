//! Bench: regenerate Table 2 — compute every constant (L, L_max, ν, ν₁,
//! ν₂, ω, 𝓛̃_max uniform/importance) and the predicted iteration
//! complexities of all six methods per dataset, then verify the headline
//! prediction (the "+" speedup factor up to min(n, d)) against a measured
//! run on one dataset.
//!
//!     cargo bench --bench table2_complexities

use smx::config::ExperimentConfig;
use smx::experiments::{runner, tables};
use smx::sampling::SamplingKind;
use smx::util::bench::bench_once;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let datasets: Vec<String> = std::env::var("SMX_BENCH_DATASETS")
        .unwrap_or_else(|_| "a1a,mushrooms,phishing,madelon,duke,a8a".to_string())
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();

    println!("== Table 2 bench: constants + predicted complexities ==\n");
    let (_, secs) = bench_once("table2 (all constants, all datasets)", || {
        tables::table2(&cfg, &datasets).unwrap()
    });
    println!("\n(constants computed in {secs:.1}s — includes 𝓛̃ water-filling per worker)\n");

    // measured sanity: predicted DIANA+ >~1 speedup should materialize
    let mut c = cfg.clone();
    c.dataset = "phishing".into();
    c.tau = 1.0;
    c.max_rounds = 40_000;
    c.target_residual = 1e-10;
    c.record_every = 100;
    let prep = runner::prepare(&c)?;
    let (r_base, _) = bench_once("measured: diana (uniform)", || {
        runner::run_one(&prep, &c, "diana", SamplingKind::Uniform, 1.0).unwrap()
    });
    let (r_plus, _) = bench_once("measured: diana+ (importance)", || {
        runner::run_one(&prep, &c, "diana+", SamplingKind::ImportanceDiana, 1.0).unwrap()
    });
    let eps = 1e-8;
    if let (Some(b), Some(p)) = (r_base.rounds_to(eps), r_plus.rounds_to(eps)) {
        println!(
            "\nmeasured speedup on phishing: {:.2}x (predicted up to min(n,d) = {})",
            b as f64 / p as f64,
            prep.sm.n().min(prep.sm.dim)
        );
    }
    Ok(())
}
