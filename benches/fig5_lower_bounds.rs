//! Bench: regenerate Figure 5 (Appendix C lower bounds) — measure the
//! (α, bits) trade-off points for random and top-k sparsification on
//! Gaussian vectors, check Theorem 14 empirically, and time the
//! compressors themselves.
//!
//!     cargo bench --bench fig5_lower_bounds

use smx::compress::{lowerbound, topk_compress, SparseMsg};
use smx::util::bench::{bench, black_box};
use smx::util::rng::Rng;

fn main() {
    let d = 1000;
    let mut rng = Rng::new(55);

    println!("== Figure 5 bench: linear-compressor lower bound ==\n");
    println!("scheme   param   alpha     beta      alpha+beta  alpha*4^(b/d)");
    let mut min_linear = f64::MAX;
    for &q in &[0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9] {
        let p = lowerbound::random_sparsification_point(d, q, &mut rng);
        min_linear = min_linear.min(p.linear_lb);
        println!(
            "random   {q:<6.2} {:<9.4} {:<9.4} {:<11.4} {:<12.4}",
            p.alpha, p.beta, p.linear_lb, p.general_up
        );
    }
    for &k in &[50usize, 100, 200, 400, 700, 900] {
        let p = lowerbound::topk_point(d, k, &mut rng);
        println!(
            "topk     {:<6.2} {:<9.4} {:<9.4} {:<11.4} {:<12.4}",
            p.param, p.alpha, p.beta, p.linear_lb, p.general_up
        );
    }
    println!("\nTheorem 14 check: min(α+β) over linear points = {min_linear:.4} (must be ≳ 1)");
    assert!(min_linear > 0.95, "linear lower bound violated");

    println!("\ncompressor micro-benches (d = {d}):");
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut msg = SparseMsg::new();
    bench("topk_compress k=100", 200, || {
        topk_compress(black_box(&x), 100, &mut msg);
    });
    let s = smx::sampling::IndependentSampling::uniform(d, 100.0);
    bench("sketch_compress tau=100", 200, || {
        smx::compress::sketch_compress(black_box(&x), &s, &mut rng, &mut msg);
    });
}
